//! Fig. 8 pipeline model: crossbar MVM dataflow with column-shared ADC
//! readout versus all-column-parallel MTJ conversion.
//!
//! A crossbar processes one (position, stream) vector per pipeline beat:
//!   stage 1: DAC drive + analog crossbar read  (t_xbar)
//!   stage 2: PS digitization                   (t_ps: ADC serial / MTJ ∥)
//!   stage 3: shift-and-add merge               (t_sna, pipelined away)
//! The beat period is the longest stage; the paper's point is that shared
//! ADCs make stage 2 the bottleneck (share × t_adc) while per-column MTJs
//! shrink it to samples × 2 ns.
//!
//! The **software realization** of the inter-layer pipeline lives in
//! `model/infer.rs` (`NativeModel::forward`): batch images fan out to
//! workers that each carry one image through every layer, so layer k of
//! image i executes while layer k−1 of image i+1 is still running —
//! exactly the tile-level overlap this model prices analytically.
//! [`PipelineModel::pipelined_batch_latency_ns`] and
//! [`software_pipeline_speedup`] bound that execution: each image's
//! network pass is one pipeline "job", workers drain jobs greedily, and
//! the makespan is `ceil(images / workers)` network latencies.

use super::components::{ComponentCosts, PsProcessing};
use super::mapper::MappedLayer;

#[derive(Debug, Clone)]
pub struct PipelineModel {
    pub costs: ComponentCosts,
    /// digital S&A merge time per beat (ns)
    pub sna_ns: f64,
}

impl Default for PipelineModel {
    fn default() -> Self {
        Self { costs: ComponentCosts::default(), sna_ns: 1.0 }
    }
}

/// Timing breakdown of one crossbar pipeline (Fig. 8 panels).
#[derive(Debug, Clone)]
pub struct StageTiming {
    pub t_xbar_ns: f64,
    pub t_ps_ns: f64,
    pub t_sna_ns: f64,
    /// pipeline beat = max stage
    pub beat_ns: f64,
}

impl PipelineModel {
    /// Stage lengths for a crossbar with `n_cols` logical columns.
    pub fn stages(&self, ps: PsProcessing, n_cols: usize) -> StageTiming {
        let t_xbar = self.costs.xbar_read_ns;
        let t_ps = self.costs.ps_stage_ns(ps, n_cols);
        let t_sna = self.sna_ns;
        StageTiming {
            t_xbar_ns: t_xbar,
            t_ps_ns: t_ps,
            t_sna_ns: t_sna,
            beat_ns: t_xbar.max(t_ps).max(t_sna),
        }
    }

    /// Latency of one layer (ns): beats = positions × streams, pipelined
    /// (fill + drain ≈ 2 extra beats).  Subarrays/slices/column tiles run
    /// in parallel hardware.
    pub fn layer_latency_ns(&self, layer: &MappedLayer, ps: PsProcessing) -> f64 {
        let beats = (layer.positions * layer.n_streams) as f64 + 2.0;
        let cols = layer.n.min(128);
        beats * self.stages(ps, cols).beat_ns
    }

    /// Whole-network latency: layers are pipelined across tiles in steady
    /// state (throughput-bound), so we report the max-stage bound plus the
    /// sum for the single-inference (latency-bound) case.
    pub fn network_latency_ns(
        &self,
        layers: &[MappedLayer],
        ps_of: impl Fn(&MappedLayer) -> PsProcessing,
    ) -> f64 {
        layers
            .iter()
            .map(|l| self.layer_latency_ns(l, ps_of(l)))
            .sum()
    }

    /// Makespan (ns) of `images` single-image network passes on the
    /// software layer pipeline with `workers` worker threads — the
    /// analytical bound on `NativeModel::forward`'s pipelined batch
    /// execution.  Workers drain images greedily and every image costs
    /// one latency-bound network pass, so the makespan is
    /// `ceil(images / workers)` network latencies (image-parallel layer
    /// overlap hides everything else).
    pub fn pipelined_batch_latency_ns(
        &self,
        layers: &[MappedLayer],
        ps_of: impl Fn(&MappedLayer) -> PsProcessing,
        images: usize,
        workers: usize,
    ) -> f64 {
        let t_net = self.network_latency_ns(layers, ps_of);
        images.div_ceil(workers.max(1)) as f64 * t_net
    }

    /// ASCII rendering of the Fig. 8 comparison for the CLI.
    pub fn render_fig8(&self, n_cols: usize, adc_share: usize, samples: u32) -> String {
        let adc = self.stages(
            PsProcessing::AdcFullPrecision { share: adc_share },
            n_cols,
        );
        let mtj = self.stages(PsProcessing::StochasticMtj { samples }, n_cols);
        let mut out = String::new();
        let bar = |t: f64, beat: f64| {
            let w = (t / beat * 40.0).round() as usize;
            "█".repeat(w.max(1))
        };
        out.push_str(&format!(
            "ADC pipeline (share={adc_share}): beat = {:.1} ns\n",
            adc.beat_ns
        ));
        out.push_str(&format!(
            "  xbar {:<40} {:.1} ns\n  adc  {:<40} {:.1} ns\n  s&a  {:<40} {:.1} ns\n",
            bar(adc.t_xbar_ns, adc.beat_ns),
            adc.t_xbar_ns,
            bar(adc.t_ps_ns, adc.beat_ns),
            adc.t_ps_ns,
            bar(adc.t_sna_ns, adc.beat_ns),
            adc.t_sna_ns,
        ));
        out.push_str(&format!(
            "MTJ pipeline (samples={samples}): beat = {:.1} ns\n",
            mtj.beat_ns
        ));
        out.push_str(&format!(
            "  xbar {:<40} {:.1} ns\n  mtj  {:<40} {:.1} ns\n  s&a  {:<40} {:.1} ns\n",
            bar(mtj.t_xbar_ns, mtj.beat_ns),
            mtj.t_xbar_ns,
            bar(mtj.t_ps_ns, mtj.beat_ns),
            mtj.t_ps_ns,
            bar(mtj.t_sna_ns, mtj.beat_ns),
            mtj.t_sna_ns,
        ));
        out.push_str(&format!(
            "speedup (beat ratio): {:.1}x\n",
            adc.beat_ns / mtj.beat_ns
        ));
        out
    }
}

/// Ideal speedup of the software layer pipeline over the sequential
/// whole-batch forward: `images / ceil(images / workers)` — linear while
/// images divide evenly over workers, degrading on the ragged tail
/// (e.g. 5 images on 4 workers still take 2 rounds).
pub fn software_pipeline_speedup(images: usize, workers: usize) -> f64 {
    if images == 0 {
        return 1.0;
    }
    images as f64 / images.div_ceil(workers.max(1)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::mapper::{map_layer, LayerShape};
    use crate::imc::StoxConfig;

    #[test]
    fn adc_stage_dominates_with_sharing() {
        let p = PipelineModel::default();
        let s = p.stages(PsProcessing::AdcFullPrecision { share: 128 }, 128);
        assert_eq!(s.t_ps_ns, 128.0);
        assert_eq!(s.beat_ns, 128.0);
    }

    #[test]
    fn mtj_beat_bounded_by_xbar_read() {
        let p = PipelineModel::default();
        let s = p.stages(PsProcessing::StochasticMtj { samples: 1 }, 128);
        // 2 ns conversion < 10 ns crossbar read → xbar-bound
        assert_eq!(s.beat_ns, p.costs.xbar_read_ns);
    }

    #[test]
    fn beat_speedup_matches_paper_magnitude() {
        // Paper: up to 8x latency improvement; the beat ratio at the
        // baseline 16:1 column sharing contributes 4x, the halved stream
        // count (8b -> 4b activations) the other 2x.
        let p = PipelineModel::default();
        let adc = p.stages(PsProcessing::AdcFullPrecision { share: 16 }, 128);
        let mtj = p.stages(PsProcessing::StochasticMtj { samples: 1 }, 128);
        let speedup = adc.beat_ns / mtj.beat_ns;
        assert!(speedup >= 2.0 && speedup < 20.0, "{speedup}");
    }

    #[test]
    fn multisampling_lengthens_mtj_stage() {
        let p = PipelineModel::default();
        let s1 = p.stages(PsProcessing::StochasticMtj { samples: 1 }, 128);
        let s8 = p.stages(PsProcessing::StochasticMtj { samples: 8 }, 128);
        assert!(s8.t_ps_ns == 8.0 * s1.t_ps_ns);
        assert!(s8.beat_ns >= s1.beat_ns);
    }

    #[test]
    fn layer_latency_scales_with_positions() {
        let p = PipelineModel::default();
        let cfg = StoxConfig::default();
        let small = map_layer(&LayerShape::conv("a", 3, 16, 16, 8, true), &cfg, 128);
        let big = map_layer(&LayerShape::conv("b", 3, 16, 16, 16, true), &cfg, 128);
        let ps = PsProcessing::StochasticMtj { samples: 1 };
        let r = p.layer_latency_ns(&big, ps) / p.layer_latency_ns(&small, ps);
        assert!((r - 4.0).abs() < 0.1, "{r}");
    }

    #[test]
    fn software_pipeline_speedup_bounds() {
        // even split: linear in workers
        assert_eq!(software_pipeline_speedup(8, 4), 4.0);
        // ragged tail: 5 images on 4 workers take 2 rounds
        assert_eq!(software_pipeline_speedup(5, 4), 2.5);
        // degenerate shapes never exceed the work available
        assert_eq!(software_pipeline_speedup(1, 16), 1.0);
        assert_eq!(software_pipeline_speedup(7, 1), 1.0);
        assert_eq!(software_pipeline_speedup(0, 4), 1.0);
        assert_eq!(software_pipeline_speedup(3, 0), 1.0);
    }

    #[test]
    fn pipelined_batch_latency_matches_round_count() {
        let p = PipelineModel::default();
        let cfg = StoxConfig::default();
        let layers = [
            map_layer(&LayerShape::conv("a", 3, 16, 16, 8, true), &cfg, 128),
            map_layer(&LayerShape::conv("b", 3, 8, 8, 16, true), &cfg, 128),
        ];
        let ps = |_: &MappedLayer| PsProcessing::StochasticMtj { samples: 1 };
        let t_net = p.network_latency_ns(&layers, ps);
        // 8 images, 4 workers → 2 rounds of the network latency
        assert_eq!(p.pipelined_batch_latency_ns(&layers, ps, 8, 4), 2.0 * t_net);
        // one worker degenerates to the sequential batch
        assert_eq!(p.pipelined_batch_latency_ns(&layers, ps, 3, 1), 3.0 * t_net);
        // speedup identity: sequential / pipelined == software speedup
        let seq = 5.0 * t_net;
        let pipe = p.pipelined_batch_latency_ns(&layers, ps, 5, 4);
        assert_eq!(seq / pipe, software_pipeline_speedup(5, 4));
    }

    #[test]
    fn fig8_renders() {
        let s = PipelineModel::default().render_fig8(128, 8, 1);
        assert!(s.contains("ADC pipeline"));
        assert!(s.contains("speedup"));
    }
}
