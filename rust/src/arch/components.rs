//! Table 2 component cost database (28 nm).
//!
//! Energies are per *action* (pJ), areas per *instance* (µm²), matching
//! the paper's Accelergy-style methodology.  ADC figures follow the SAR
//! survey scaling [Murmann]; DAC/crossbar-cell figures follow PUMA/ISAAC;
//! the MTJ converter row comes from our `device::converter` model
//! (calibrated to the paper's 6.14 fJ / 1.47 µm²).

use crate::device::converter as devconv;

/// How array-level partial sums are digitized — the design axis of the
/// paper.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PsProcessing {
    /// full-precision SAR ADC, `share` columns time-multiplexed per ADC
    AdcFullPrecision { share: usize },
    /// reduced-precision "sparse" ADC (paper's SFA baseline)
    AdcSparse { share: usize },
    /// deterministic 1-bit sense amplifier per column
    SenseAmp,
    /// stochastic SOT-MTJ converter per column, `samples` reads/conversion
    StochasticMtj { samples: u32 },
    /// stochastic SOT-MTJ converter whose *mean* read count is fractional
    /// (`millisamples` = 1000 × mean reads/conversion) — the exact energy
    /// accounting of §3.2.3 inhomogeneous sampling, whose per-(stream,
    /// slice) read counts average to a non-integer.  Energy and pipeline
    /// beat scale with the exact mean instead of the rounded one.
    StochasticMtjFrac { millisamples: u32 },
}

impl PsProcessing {
    pub fn label(&self) -> String {
        match self {
            PsProcessing::AdcFullPrecision { .. } => "FP-ADC".into(),
            PsProcessing::AdcSparse { .. } => "Sparse-ADC".into(),
            PsProcessing::SenseAmp => "1b-SA".into(),
            PsProcessing::StochasticMtj { samples } => format!("MTJ×{samples}"),
            PsProcessing::StochasticMtjFrac { millisamples } => {
                format!("MTJ×{}", *millisamples as f64 / 1000.0)
            }
        }
    }

    /// Temporal samples consumed per PS conversion (1 except multi-sample
    /// MTJ; the fractional variant reports its mean rounded half-up —
    /// whole conversions are counted even when the energy charge is
    /// fractional).
    pub fn samples(&self) -> u32 {
        match self {
            PsProcessing::StochasticMtj { samples } => *samples,
            PsProcessing::StochasticMtjFrac { millisamples } => {
                ((millisamples + 500) / 1000).max(1)
            }
            _ => 1,
        }
    }
}

/// Per-action energy (pJ) / per-instance area (µm²) / per-action latency
/// (ns) for every component in Fig. 6.
#[derive(Debug, Clone, Copy)]
pub struct ComponentCosts {
    pub dac_energy_pj: f64,
    pub dac_area_um2: f64,
    /// crossbar cell read energy, 1 bit/cell
    pub cell_energy_1b_pj: f64,
    /// crossbar cell read energy, 2 bits/cell
    pub cell_energy_2b_pj: f64,
    pub cell_area_um2: f64,
    pub adc_fp_energy_pj: f64,
    pub adc_fp_area_um2: f64,
    pub adc_sparse_energy_pj: f64,
    pub adc_sparse_area_um2: f64,
    pub mtj_energy_pj: f64,
    pub mtj_area_um2: f64,
    /// 1-bit sense amp (limit of the reconfigurable ADC; tiny)
    pub sa_energy_pj: f64,
    pub sa_area_um2: f64,
    /// shift-and-add / counter datapath per PS merge
    pub sna_energy_pj: f64,
    pub sna_area_um2: f64,
    /// per-conversion latencies (ns)
    pub adc_latency_ns: f64,
    pub mtj_latency_ns: f64,
    pub sa_latency_ns: f64,
    /// crossbar analog read (row activation → settled columns)
    pub xbar_read_ns: f64,
    /// eDRAM buffer + bus + router energy per activation access
    /// (ISAAC-style tile I/O; calibrated so ADC ≈ 80% of HPFA energy,
    /// the paper's quoted 60-80% band)
    pub io_energy_pj: f64,
    /// per-crossbar digital overhead area: eDRAM slice, router share,
    /// control (calibrated so ADC ≈ 70% of HPFA area)
    pub tile_overhead_um2: f64,
}

impl Default for ComponentCosts {
    fn default() -> Self {
        Self {
            // Table 2 rows
            dac_energy_pj: 2.99e-2,
            dac_area_um2: 0.127,
            cell_energy_1b_pj: 6.16e-3,
            cell_energy_2b_pj: 4.16e-3,
            cell_area_um2: 0.0308,
            adc_fp_energy_pj: 2.137,
            adc_fp_area_um2: 6600.0,
            adc_sparse_energy_pj: 1.171,
            adc_sparse_area_um2: 2700.0,
            mtj_energy_pj: devconv::PAPER_ENERGY_PER_CONVERSION_J * 1e12,
            mtj_area_um2: devconv::PAPER_AREA_UM2,
            // supporting digital (Accelergy 28nm-class values)
            sa_energy_pj: 1.0e-3,
            sa_area_um2: 1.2,
            sna_energy_pj: 4.1e-3,
            sna_area_um2: 28.0,
            adc_latency_ns: 1.0, // 1 GS/s SAR
            mtj_latency_ns: devconv::PAPER_LATENCY_S * 1e9,
            sa_latency_ns: 0.5,
            xbar_read_ns: 4.0,
            io_energy_pj: 0.18,
            tile_overhead_um2: 15_000.0,
        }
    }
}

impl ComponentCosts {
    /// Converter energy per PS conversion event (pJ).
    pub fn ps_energy_pj(&self, ps: PsProcessing) -> f64 {
        match ps {
            PsProcessing::AdcFullPrecision { .. } => self.adc_fp_energy_pj,
            PsProcessing::AdcSparse { .. } => self.adc_sparse_energy_pj,
            PsProcessing::SenseAmp => self.sa_energy_pj,
            PsProcessing::StochasticMtj { samples } => {
                self.mtj_energy_pj * samples as f64
            }
            PsProcessing::StochasticMtjFrac { millisamples } => {
                self.mtj_energy_pj * (millisamples as f64 / 1000.0)
            }
        }
    }

    /// Converter area per *logical column* (µm²): shared ADCs amortize.
    pub fn ps_area_per_column_um2(&self, ps: PsProcessing) -> f64 {
        match ps {
            PsProcessing::AdcFullPrecision { share } => {
                self.adc_fp_area_um2 / share as f64
            }
            PsProcessing::AdcSparse { share } => {
                self.adc_sparse_area_um2 / share as f64
            }
            PsProcessing::SenseAmp => self.sa_area_um2,
            PsProcessing::StochasticMtj { .. } | PsProcessing::StochasticMtjFrac { .. } => {
                self.mtj_area_um2
            }
        }
    }

    /// Time to digitize all `n_cols` columns of one crossbar read
    /// (the pipeline stage length of Fig. 8).
    pub fn ps_stage_ns(&self, ps: PsProcessing, n_cols: usize) -> f64 {
        match ps {
            PsProcessing::AdcFullPrecision { share }
            | PsProcessing::AdcSparse { share } => {
                // each ADC serially reads its shared columns
                let per_adc = n_cols.min(share);
                self.adc_latency_ns * per_adc as f64
            }
            PsProcessing::SenseAmp => self.sa_latency_ns,
            PsProcessing::StochasticMtj { samples } => {
                self.mtj_latency_ns * samples as f64
            }
            PsProcessing::StochasticMtjFrac { millisamples } => {
                self.mtj_latency_ns * (millisamples as f64 / 1000.0)
            }
        }
    }

    pub fn cell_energy_pj(&self, bits_per_cell: u32) -> f64 {
        if bits_per_cell >= 2 {
            self.cell_energy_2b_pj
        } else {
            self.cell_energy_1b_pj
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values_present() {
        let c = ComponentCosts::default();
        assert_eq!(c.dac_energy_pj, 2.99e-2);
        assert_eq!(c.adc_fp_energy_pj, 2.137);
        assert_eq!(c.adc_sparse_area_um2, 2700.0);
        assert!((c.mtj_energy_pj - 6.14e-3).abs() < 1e-6);
        assert!((c.mtj_area_um2 - 1.47).abs() < 1e-9);
    }

    #[test]
    fn mtj_vs_adc_orders_of_magnitude() {
        let c = ComponentCosts::default();
        let ratio = c.adc_fp_energy_pj
            / c.ps_energy_pj(PsProcessing::StochasticMtj { samples: 1 });
        assert!(ratio > 100.0, "energy ratio {ratio}");
    }

    #[test]
    fn shared_adc_amortizes_area_not_latency() {
        let c = ComponentCosts::default();
        let a8 = c.ps_area_per_column_um2(PsProcessing::AdcFullPrecision { share: 8 });
        let a128 =
            c.ps_area_per_column_um2(PsProcessing::AdcFullPrecision { share: 128 });
        assert!(a8 > a128);
        let t8 = c.ps_stage_ns(PsProcessing::AdcFullPrecision { share: 8 }, 128);
        let t128 = c.ps_stage_ns(PsProcessing::AdcFullPrecision { share: 128 }, 128);
        assert!(t128 > t8, "more sharing -> longer serial readout");
    }

    #[test]
    fn mtj_stage_parallel_over_columns() {
        let c = ComponentCosts::default();
        let t_small = c.ps_stage_ns(PsProcessing::StochasticMtj { samples: 1 }, 8);
        let t_big = c.ps_stage_ns(PsProcessing::StochasticMtj { samples: 1 }, 512);
        assert_eq!(t_small, t_big, "column-parallel conversion");
    }

    #[test]
    fn multi_sampling_scales_energy_linearly() {
        let c = ComponentCosts::default();
        let e1 = c.ps_energy_pj(PsProcessing::StochasticMtj { samples: 1 });
        let e8 = c.ps_energy_pj(PsProcessing::StochasticMtj { samples: 8 });
        assert!((e8 / e1 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn fractional_samples_are_exact() {
        let c = ComponentCosts::default();
        let frac = PsProcessing::StochasticMtjFrac { millisamples: 2500 };
        let e1 = c.ps_energy_pj(PsProcessing::StochasticMtj { samples: 1 });
        assert!((c.ps_energy_pj(frac) / e1 - 2.5).abs() < 1e-9);
        // integral millisamples reduce to the whole-sample charge exactly
        assert_eq!(
            c.ps_energy_pj(PsProcessing::StochasticMtjFrac { millisamples: 3000 }),
            c.ps_energy_pj(PsProcessing::StochasticMtj { samples: 3 })
        );
        assert_eq!(
            c.ps_stage_ns(frac, 128),
            c.ps_stage_ns(PsProcessing::StochasticMtj { samples: 1 }, 128) * 2.5
        );
        assert_eq!(
            c.ps_area_per_column_um2(frac),
            c.ps_area_per_column_um2(PsProcessing::StochasticMtj { samples: 1 })
        );
        // whole-conversion count rounds half up; label shows the mean
        assert_eq!(frac.samples(), 3);
        assert_eq!(PsProcessing::StochasticMtjFrac { millisamples: 2499 }.samples(), 2);
        assert_eq!(PsProcessing::StochasticMtjFrac { millisamples: 400 }.samples(), 1);
        assert_eq!(frac.label(), "MTJ×2.5");
    }
}
