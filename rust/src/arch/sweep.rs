//! Registry-driven accuracy × energy Pareto sweep — the evaluation layer
//! that turns the open [`PsConvert`](crate::imc::PsConvert) API into the
//! paper's actual trade-off story (Fig. 9: stochastic PS processing buys
//! 24–130× EDP over ADC baselines while holding near-software accuracy).
//!
//! The sweep is the paper's full §4 *design matrix*, two axes:
//!
//! * the **precision axis** — `XwYaZbs` [`StoxConfig`] tags
//!   ([`parse_precision_tags`], e.g. `4w4a4bs,8w8a4bs`), and
//! * the **PS-processing axis** — converter specs (every mode registered
//!   in the [`ConverterRegistry`](crate::imc::ConverterRegistry), plus MTJ
//!   sample-length and ADC bit-width grids, [`default_grid`]).
//!
//! Every (tag, spec) cell measures task accuracy on a deterministic golden
//! workload (or a checkpoint), joins with the [`energy`](super::energy)
//! rollup through [`PsConvert::cost_key`](crate::imc::PsConvert::cost_key),
//! and lands on one (accuracy ↑, EDP ↓) front — so the HPFA-class
//! (`ideal` at 8-bit tags), SFA-class (`sparse`) and StoX (`stox` /
//! `inhomo`) design points are directly comparable, as in Fig. 9a.  Cells
//! fan out across threads with [`par_map`]; results are bit-identical for
//! every thread count because each point is a pure function of
//! `(tag, spec, seed)`.
//!
//! Entry points: [`parse_precision_tags`] + [`default_grid`] →
//! [`run_matrix_sweep`] (or the single-tag [`run_sweep`]) →
//! [`SweepResult`] (JSON / CSV / markdown table).  The CLI front-end is
//! `stox-cli sweep`; `examples/efficiency_sweep.rs` and
//! `rust/benches/sweep.rs` drive the same path.

use super::components::{ComponentCosts, PsProcessing};
use super::energy::{evaluate_design, CounterTotals, DesignConfig, MeasuredEnergy};
use super::mapper::LayerShape;
use crate::obs::CounterRegistry;
use crate::imc::{
    default_registry, IdealAdcConv, PsConvert, PsConverterSpec, StoxConfig, StoxMvm,
};
use crate::stats::rng::CounterRng;
use crate::util::json::Json;
use crate::util::pool::par_map;

/// One evaluated design point of the sweep: a (precision tag, converter
/// spec) cell joined with its task accuracy and its architecture cost
/// rollup.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Precision tag of the [`StoxConfig`] this cell ran at (`XwYaZbs`,
    /// [`StoxConfig::tag`]) — the Fig. 9a precision axis.
    pub tag: String,
    /// Canonical spec string (`name[:k=v,..]`) — parseable by
    /// [`PsConverterSpec::from_mode`] / `--converter`.
    pub spec: String,
    /// Human-readable converter label ([`PsConvert::label`]).
    pub label: String,
    /// Task accuracy in [0, 1] on the golden workload (1.0 = matches the
    /// infinite-precision readout on every input).
    pub accuracy: f64,
    /// Network energy per inference (pJ).
    pub energy_pj: f64,
    /// Network latency per inference (ns).
    pub latency_ns: f64,
    /// Total silicon area (µm²).
    pub area_um2: f64,
    /// Energy-delay product (pJ·ns) — the paper's headline axis.
    pub edp_pj_ns: f64,
    /// Total PS conversions (temporal samples included).
    pub conversions: u64,
    /// Crossbar instances required.
    pub xbars: usize,
    /// Whether the point sits on the non-dominated (accuracy, EDP) front.
    pub on_front: bool,
}

/// One cell of the measured-vs-analytical energy cross-check: the
/// analytic [`evaluate_design`] prediction on the golden-workload layers
/// next to the counter-priced energy of actually running them
/// ([`GoldenWorkload::measure_energy`]).
#[derive(Debug, Clone)]
pub struct MeasuredCell {
    pub tag: String,
    pub spec: String,
    /// analytic energy per inference on the golden-workload layers (pJ)
    pub predicted_pj: f64,
    /// counter-priced energy per inference from running them (pJ)
    pub measured_pj: f64,
    /// `|measured − predicted| / predicted`
    pub rel_err: f64,
    /// multi-/fractional-sample MTJ cost key — reported, but exempt from
    /// the exact-converter cross-check bound
    pub stochastic_cost: bool,
}

impl MeasuredCell {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tag", Json::Str(self.tag.clone())),
            ("spec", Json::Str(self.spec.clone())),
            ("predicted_pj", Json::Num(self.predicted_pj)),
            ("measured_pj", Json::Num(self.measured_pj)),
            ("rel_err", Json::Num(self.rel_err)),
            ("stochastic_cost", Json::Bool(self.stochastic_cost)),
        ])
    }
}

/// Render the measured-vs-analytical cells as a markdown-style table
/// (the `sweep --measured` CLI output).
pub fn render_measured_table(cells: &[MeasuredCell]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "| {:<8} | {:<28} | {:>14} | {:>14} | {:>9} |\n",
        "tag", "spec", "predicted pJ", "measured pJ", "rel err"
    ));
    s.push_str(&format!(
        "|{:-<10}|{:-<30}|{:->16}|{:->16}|{:->11}|\n",
        "", "", "", "", ""
    ));
    for c in cells {
        s.push_str(&format!(
            "| {:<8} | {:<28} | {:>14.3} | {:>14.3} | {:>8.4}% |\n",
            c.tag,
            c.spec,
            c.predicted_pj,
            c.measured_pj,
            100.0 * c.rel_err,
        ));
    }
    s
}

/// Run the measured-vs-analytical cross-check over a whole sweep grid:
/// one [`GoldenWorkload`] per precision tag, one measured forward per
/// `(tag, spec)` cell, sequentially (each cell re-attaches counters to
/// the workload's crossbars).  Cells whose config falls outside the
/// integer-kernel bound are skipped — they have no counters to measure.
pub fn measure_grid(
    grid: &[(StoxConfig, Vec<PsConverterSpec>)],
    n_inputs: usize,
    seed: u32,
) -> crate::Result<Vec<MeasuredCell>> {
    let costs = ComponentCosts::default();
    let mut cells = Vec::new();
    for (cfg, specs) in grid {
        let mut gw = GoldenWorkload::new(*cfg, n_inputs, seed)?;
        for spec in specs {
            if let Some(cell) = gw.measure_energy(spec, &costs)? {
                cells.push(cell);
            }
        }
    }
    Ok(cells)
}

/// A completed sweep: points sorted by ascending EDP (ties: accuracy
/// descending, then tag, then spec), with the Pareto front marked.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Workload name the energy rollup was evaluated on.
    pub workload: String,
    /// Golden-workload seed (the whole sweep is a pure function of it).
    pub seed: u32,
    /// All evaluated points, EDP-ascending.
    pub points: Vec<SweepPoint>,
}

/// Non-dominated flags for (accuracy ↑, edp ↓) pairs, in input order.
///
/// A point is dominated iff some other point has `edp <= e && acc >= a`
/// with at least one strict inequality; of exact duplicates only the
/// first (in the deterministic EDP/accuracy/index order) is kept on the
/// front.  Pure and deterministic — property-tested in
/// `rust/tests/sweep.rs`.
pub fn pareto_front_flags(acc_edp: &[(f64, f64)]) -> Vec<bool> {
    let mut order: Vec<usize> = (0..acc_edp.len()).collect();
    order.sort_by(|&a, &b| {
        acc_edp[a]
            .1
            .total_cmp(&acc_edp[b].1)
            .then(acc_edp[b].0.total_cmp(&acc_edp[a].0))
            .then(a.cmp(&b))
    });
    let mut flags = vec![false; acc_edp.len()];
    let mut best_acc = f64::NEG_INFINITY;
    for &i in &order {
        if acc_edp[i].0 > best_acc {
            flags[i] = true;
            best_acc = acc_edp[i].0;
        }
    }
    flags
}

/// Parse a sweep grid string: comma-separated integers and/or inclusive
/// `lo..hi` ranges (`"1,2,4..6"` → `[1, 2, 4, 5, 6]`).
pub fn parse_grid(s: &str) -> crate::Result<Vec<u32>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = tok.split_once("..") {
            let lo: u32 = lo
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad grid range '{tok}'"))?;
            let hi: u32 = hi
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad grid range '{tok}'"))?;
            anyhow::ensure!(lo <= hi, "empty grid range '{tok}'");
            out.extend(lo..=hi);
        } else {
            out.push(
                tok.parse()
                    .map_err(|_| anyhow::anyhow!("bad grid value '{tok}'"))?,
            );
        }
    }
    anyhow::ensure!(!out.is_empty(), "empty sweep grid '{s}'");
    Ok(out)
}

/// Parse the precision axis of the design matrix: a comma-separated list
/// of `XwYa[Zbs]` tags (`"4w4a4bs,8w8a4bs"`) into [`StoxConfig`]s derived
/// from `base` via [`StoxConfig::from_tag`].  Duplicate tags are dropped
/// (first occurrence wins); an empty list is an error.
pub fn parse_precision_tags(s: &str, base: &StoxConfig) -> crate::Result<Vec<StoxConfig>> {
    let mut out: Vec<StoxConfig> = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let cfg = StoxConfig::from_tag(tok, base)?;
        if !out.iter().any(|c| c.tag() == cfg.tag()) {
            out.push(cfg);
        }
    }
    anyhow::ensure!(!out.is_empty(), "empty precision-tag list '{s}'");
    Ok(out)
}

/// The default sweep grid: one default-parameter spec per registered
/// converter mode, an MTJ sample-length grid (`stox:samples=…` plus the
/// matching §3.2.3 `inhomo:base=1,extra=…` points), and ADC bit-width
/// grids for both the plain and the sparsity-aware ADC.  Duplicates
/// (by canonical spec string) are dropped, first occurrence wins.
pub fn default_grid(
    cfg: &StoxConfig,
    mtj_samples: &[u32],
    adc_bits: &[u32],
) -> Vec<PsConverterSpec> {
    let mut specs: Vec<PsConverterSpec> = Vec::new();
    let mut seen: Vec<String> = Vec::new();
    let push = |s: PsConverterSpec, seen: &mut Vec<String>, specs: &mut Vec<PsConverterSpec>| {
        let key = s.to_string();
        if !seen.contains(&key) {
            seen.push(key);
            specs.push(s);
        }
    };
    for name in default_registry().names() {
        if let Ok(s) = PsConverterSpec::from_mode(name, cfg.alpha, cfg.n_samples) {
            push(s, &mut seen, &mut specs);
        }
    }
    for &n in mtj_samples {
        let n = n.max(1);
        push(
            PsConverterSpec::StochasticMtj { alpha: cfg.alpha, n_samples: n },
            &mut seen,
            &mut specs,
        );
        if n > 1 {
            push(
                PsConverterSpec::InhomogeneousMtj {
                    alpha: cfg.alpha,
                    base_samples: 1,
                    extra_samples: n - 1,
                },
                &mut seen,
                &mut specs,
            );
        }
    }
    for &b in adc_bits {
        let b = b.clamp(1, 16);
        push(PsConverterSpec::QuantAdc { bits: b }, &mut seen, &mut specs);
        push(PsConverterSpec::SparseAdc { bits: b }, &mut seen, &mut specs);
    }
    specs
}

/// First-max argmax: ties resolve to the lowest index, matching numpy/jnp
/// `argmax` — the tie-breaking rule shared by the golden workload, CLI
/// serving, and [`NativeModel::accuracy`](crate::model::NativeModel)
/// so accuracies are comparable across paths.
pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

fn scale_clamp(x: &[f32], gain: f32) -> Vec<f32> {
    x.iter().map(|v| (v * gain).clamp(-1.0, 1.0)).collect()
}

/// Deterministic golden workload for converter-accuracy measurement: a
/// two-layer crossbar-mapped classifier with seeded random weights and
/// inputs, labeled by its own infinite-precision (ideal-ADC) readout.
///
/// Accuracy of a converter spec = fraction of golden inputs whose argmax
/// class under that converter matches the ideal-readout label, so the
/// ideal ADC scores exactly 1.0 and every lossy converter scores its
/// end-to-end task fidelity.  Everything — weights, inputs, labels,
/// stochastic draws — derives from [`CounterRng`], so a `(cfg, n, seed)`
/// triple fully determines the result on every platform and thread count.
/// This is what lets `stox-cli sweep` run without trained artifacts; pass
/// `--model` to use checkpoint accuracy instead.
pub struct GoldenWorkload {
    cfg: StoxConfig,
    mvm1: StoxMvm,
    mvm2: StoxMvm,
    inputs: Vec<f32>,
    labels: Vec<usize>,
    /// frozen inter-layer gain (from the ideal run) so every converter
    /// sees identically-scaled second-layer activations
    gain: f32,
    n_inputs: usize,
    classes: usize,
    seed: u32,
}

impl GoldenWorkload {
    /// Input features of the synthetic classifier.
    pub const FEATURES: usize = 96;
    /// Hidden width.
    pub const HIDDEN: usize = 32;
    /// Output classes.
    pub const CLASSES: usize = 10;

    /// Build the workload: program both layers, fix the inter-layer gain
    /// and the golden labels from the ideal-converter reference run.
    pub fn new(cfg: StoxConfig, n_inputs: usize, seed: u32) -> crate::Result<Self> {
        anyhow::ensure!(n_inputs > 0, "golden workload needs >= 1 input");
        let (m, h, classes) = (Self::FEATURES, Self::HIDDEN, Self::CLASSES);
        // weights/inputs draw from a seed distinct from both conversion
        // seeds (`seed`, `seed ^ 0x9E37_79B9`): the MVM's stochastic
        // converters reuse the same (seed, counter) hash space, and
        // sharing it would correlate MTJ flips with the data under test
        let rng = CounterRng::new(seed ^ 0x5EED_DA7A);
        let w1: Vec<f32> = (0..m * h)
            .map(|i| rng.uniform_in(i as u32, -1.0, 1.0))
            .collect();
        let w2: Vec<f32> = (0..h * classes)
            .map(|i| rng.uniform_in((m * h + i) as u32, -1.0, 1.0))
            .collect();
        let base = m * h + h * classes;
        let inputs: Vec<f32> = (0..n_inputs * m)
            .map(|i| rng.uniform_in((base + i) as u32, -1.0, 1.0))
            .collect();
        let mvm1 = StoxMvm::program(&w1, m, h, cfg)?;
        let mvm2 = StoxMvm::program(&w2, h, classes, cfg)?;

        // reference pass: the ideal readout defines both the inter-layer
        // gain (so quantized activations span [-1, 1]) and the labels
        let ideal = IdealAdcConv;
        let o1 = mvm1.run(&inputs, n_inputs, &ideal, seed);
        let max_abs = o1.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
        let gain = if max_abs > 0.0 { 1.0 / max_abs } else { 1.0 };
        let h1 = scale_clamp(&o1, gain);
        let o2 = mvm2.run(&h1, n_inputs, &ideal, seed ^ 0x9E37_79B9);
        let labels: Vec<usize> = (0..n_inputs)
            .map(|i| argmax(&o2[i * classes..(i + 1) * classes]))
            .collect();
        Ok(Self { cfg, mvm1, mvm2, inputs, labels, gain, n_inputs, classes, seed })
    }

    /// Hardware config the workload's crossbars were programmed with.
    pub fn cfg(&self) -> &StoxConfig {
        &self.cfg
    }

    /// Number of golden inputs.
    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// The synthetic classifier's two layers as the
    /// [`mapper`](super::mapper) sees them: 1×1 "convolutions" with one
    /// output position, so one golden input is exactly one inference.
    pub fn layer_shapes() -> Vec<LayerShape> {
        vec![
            LayerShape::conv("golden_l0", 1, Self::FEATURES, Self::HIDDEN, 1, true),
            LayerShape::conv("golden_l1", 1, Self::HIDDEN, Self::CLASSES, 1, true),
        ]
    }

    /// Run the workload's forward under `spec` with hardware counters
    /// attached, and price the measured action counts against the
    /// analytic rollup on the same layer shapes — one cell of the
    /// measured-vs-analytical EDP cross-check (`stox-cli sweep
    /// --measured`).  Returns `None` when the crossbars hold the f32
    /// reference layout (no integer kernel → no counters to measure).
    pub fn measure_energy(
        &mut self,
        spec: &PsConverterSpec,
        costs: &ComponentCosts,
    ) -> crate::Result<Option<MeasuredCell>> {
        let conv = spec.build(&self.cfg)?;
        let reg = CounterRegistry::new();
        let tag = self.cfg.tag();
        self.mvm1.attach_counters(&reg, &format!("imc.l00.{tag}."));
        self.mvm2.attach_counters(&reg, &format!("imc.l01.{tag}."));
        let o1 = self.mvm1.run_sequential(&self.inputs, self.n_inputs, conv.as_ref(), self.seed);
        let h1 = scale_clamp(&o1, self.gain);
        let _ = self
            .mvm2
            .run_sequential(&h1, self.n_inputs, conv.as_ref(), self.seed ^ 0x9E37_79B9);
        self.mvm1.detach_counters();
        self.mvm2.detach_counters();
        let totals = CounterTotals::from_snapshot(&reg.snapshot());
        if totals.conversions == 0 {
            return Ok(None);
        }
        let design = DesignConfig::from_specs(self.cfg, spec, spec)?;
        let predicted =
            evaluate_design(costs, &design, &Self::layer_shapes()).energy_pj;
        let measured =
            MeasuredEnergy::from_counters(costs, &design, &totals, self.n_inputs as u64)?
                .energy_pj;
        let rel_err = if predicted > 0.0 {
            (measured - predicted).abs() / predicted
        } else {
            f64::INFINITY
        };
        let stochastic_cost = matches!(
            design.ps,
            PsProcessing::StochasticMtj { .. } | PsProcessing::StochasticMtjFrac { .. }
        );
        Ok(Some(MeasuredCell {
            tag,
            spec: spec.to_string(),
            predicted_pj: predicted,
            measured_pj: measured,
            rel_err,
            stochastic_cost,
        }))
    }

    /// Task accuracy of `conv` against the golden labels.
    pub fn accuracy(&self, conv: &dyn PsConvert) -> f64 {
        let o1 = self.mvm1.run(&self.inputs, self.n_inputs, conv, self.seed);
        let h1 = scale_clamp(&o1, self.gain);
        let o2 = self.mvm2.run(&h1, self.n_inputs, conv, self.seed ^ 0x9E37_79B9);
        let mut correct = 0usize;
        for (i, &lab) in self.labels.iter().enumerate() {
            if argmax(&o2[i * self.classes..(i + 1) * self.classes]) == lab {
                correct += 1;
            }
        }
        correct as f64 / self.n_inputs as f64
    }
}

fn round_to(x: f64, decimals: i32) -> f64 {
    let f = 10f64.powi(decimals);
    (x * f).round() / f
}

/// Run the full two-axis design-matrix sweep (Fig. 9a): for every
/// `(precision tag, converter spec)` cell of `grid`, build the converter,
/// measure accuracy via `accuracy_fn(tag_index, spec)`, evaluate the
/// [`DesignConfig::from_specs`] cost rollup over `layers` at that tag's
/// config, and mark one joint (accuracy, EDP) Pareto front across the
/// whole matrix.
///
/// `grid` pairs each tag config with its own spec list (callers usually
/// reuse one [`default_grid`] per tag); duplicate `(tag, spec)` cells are
/// dropped, first occurrence wins.  Cells fan out over up to `threads` OS
/// threads ([`par_map`]); the result is identical for every thread count.
/// Costs are rounded (3 decimals pJ/ns/µm², 1 decimal pJ·ns) so emitted
/// artifacts are stable under f64 formatting.
pub fn run_matrix_sweep<F>(
    grid: &[(StoxConfig, Vec<PsConverterSpec>)],
    layers: &[LayerShape],
    workload: &str,
    seed: u32,
    threads: usize,
    accuracy_fn: F,
) -> crate::Result<SweepResult>
where
    F: Fn(usize, &PsConverterSpec) -> crate::Result<f64> + Sync,
{
    anyhow::ensure!(!grid.is_empty(), "matrix sweep needs at least one precision tag");
    // flatten to (tag index, spec) cells, dropping duplicate cells
    let mut cells: Vec<(usize, PsConverterSpec)> = Vec::new();
    let mut seen: Vec<(String, String)> = Vec::new();
    for (ti, (cfg, specs)) in grid.iter().enumerate() {
        cfg.validate()?;
        anyhow::ensure!(
            !specs.is_empty(),
            "no converter specs for precision tag {}",
            cfg.tag()
        );
        for spec in specs {
            let key = (cfg.tag(), spec.to_string());
            if !seen.contains(&key) {
                seen.push(key);
                cells.push((ti, spec.clone()));
            }
        }
    }
    let costs = ComponentCosts::default();
    let evaluated: Vec<crate::Result<SweepPoint>> =
        par_map(cells.len(), threads.max(1), |i| {
            let (ti, spec) = &cells[i];
            let cfg = &grid[*ti].0;
            let conv = spec.build(cfg)?;
            let accuracy = accuracy_fn(*ti, spec)?;
            // uniform design point: the swept converter runs on every
            // crossbar-mapped layer (first layer included), so EDP ranks
            // (tag, converter) cells one-on-one as in Fig. 9
            let design = DesignConfig::from_specs(*cfg, spec, spec)?;
            let report = evaluate_design(&costs, &design, layers);
            Ok(SweepPoint {
                tag: cfg.tag(),
                spec: spec.to_string(),
                label: conv.label(),
                accuracy,
                energy_pj: round_to(report.energy_pj, 3),
                latency_ns: round_to(report.latency_ns, 3),
                area_um2: round_to(report.area_um2, 3),
                edp_pj_ns: round_to(report.edp_pj_ns, 1),
                conversions: report.conversions,
                xbars: report.xbars,
                on_front: false,
            })
        });
    let mut points = Vec::with_capacity(evaluated.len());
    for p in evaluated {
        points.push(p?);
    }
    points.sort_by(|a, b| {
        a.edp_pj_ns
            .total_cmp(&b.edp_pj_ns)
            .then(b.accuracy.total_cmp(&a.accuracy))
            .then(a.tag.cmp(&b.tag))
            .then(a.spec.cmp(&b.spec))
    });
    let pairs: Vec<(f64, f64)> =
        points.iter().map(|p| (p.accuracy, p.edp_pj_ns)).collect();
    for (p, f) in points.iter_mut().zip(pareto_front_flags(&pairs)) {
        p.on_front = f;
    }
    Ok(SweepResult { workload: workload.to_string(), seed, points })
}

/// Single-tag convenience over [`run_matrix_sweep`]: sweep `specs` at one
/// hardware config `cfg` (the pre-matrix behaviour of `stox-cli sweep`).
pub fn run_sweep<F>(
    specs: &[PsConverterSpec],
    cfg: &StoxConfig,
    layers: &[LayerShape],
    workload: &str,
    seed: u32,
    threads: usize,
    accuracy_fn: F,
) -> crate::Result<SweepResult>
where
    F: Fn(&PsConverterSpec) -> crate::Result<f64> + Sync,
{
    anyhow::ensure!(!specs.is_empty(), "sweep needs at least one spec");
    let grid = [(*cfg, specs.to_vec())];
    run_matrix_sweep(&grid, layers, workload, seed, threads, |_, spec| accuracy_fn(spec))
}

impl SweepResult {
    /// Points on the non-dominated front, EDP-ascending.
    pub fn front(&self) -> Vec<&SweepPoint> {
        self.points.iter().filter(|p| p.on_front).collect()
    }

    /// Find a point by its canonical spec string — the *first* (cheapest
    /// EDP) match when a matrix sweep evaluated the spec at several
    /// precision tags; use [`SweepResult::point_at`] to pin the tag.
    pub fn point(&self, spec: &str) -> Option<&SweepPoint> {
        self.points.iter().find(|p| p.spec == spec)
    }

    /// Find the (precision tag, spec) cell of a matrix sweep.
    pub fn point_at(&self, tag: &str, spec: &str) -> Option<&SweepPoint> {
        self.points.iter().find(|p| p.tag == tag && p.spec == spec)
    }

    /// Canonical JSON form (sorted object keys, EDP-ascending points) —
    /// byte-stable for a fixed `(grid, seed)` input; pinned by the
    /// golden-file test in `rust/tests/sweep.rs`.
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("tag", Json::Str(p.tag.clone())),
                    ("spec", Json::Str(p.spec.clone())),
                    ("label", Json::Str(p.label.clone())),
                    ("accuracy", Json::Num(p.accuracy)),
                    ("energy_pj", Json::Num(p.energy_pj)),
                    ("latency_ns", Json::Num(p.latency_ns)),
                    ("area_um2", Json::Num(p.area_um2)),
                    ("edp_pj_ns", Json::Num(p.edp_pj_ns)),
                    ("conversions", Json::Num(p.conversions as f64)),
                    ("xbars", Json::Num(p.xbars as f64)),
                    ("on_front", Json::Bool(p.on_front)),
                ])
            })
            .collect();
        let front: Vec<Json> = self
            .front()
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("tag", Json::Str(p.tag.clone())),
                    ("spec", Json::Str(p.spec.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("workload", Json::Str(self.workload.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("points", Json::Arr(points)),
            ("front", Json::Arr(front)),
        ])
    }

    /// CSV form (header + one row per point, same order as the JSON).
    /// Spec and label are quoted — canonical spec strings contain commas
    /// (`stox:alpha=4,samples=1`).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "tag,spec,label,accuracy,energy_pj,latency_ns,area_um2,edp_pj_ns,conversions,xbars,on_front\n",
        );
        for p in &self.points {
            s.push_str(&format!(
                "{},\"{}\",\"{}\",{:.6},{:.3},{:.3},{:.3},{:.1},{},{},{}\n",
                p.tag,
                p.spec,
                p.label,
                p.accuracy,
                p.energy_pj,
                p.latency_ns,
                p.area_um2,
                p.edp_pj_ns,
                p.conversions,
                p.xbars,
                p.on_front,
            ));
        }
        s
    }

    /// Markdown-style summary table (`*` marks the Pareto front), plus
    /// the front as `tag spec` cells and the paper's headline: the EDP
    /// gain of the cheapest stochastic-MTJ cell over the *most expensive*
    /// full-precision-ADC cell (HPFA sits at the high-precision tag, as
    /// in Fig. 9a).
    pub fn render_table(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "| {:<8} | {:<28} | {:<16} | {:>7} | {:>12} | {:>11} | {:>14} | {:>5} |\n",
            "tag", "spec", "label", "acc %", "energy nJ", "latency µs", "EDP pJ·ns", "front"
        ));
        s.push_str(&format!(
            "|{:-<10}|{:-<30}|{:-<18}|{:->9}|{:->14}|{:->13}|{:->16}|{:->7}|\n",
            "", "", "", "", "", "", "", ""
        ));
        for p in &self.points {
            s.push_str(&format!(
                "| {:<8} | {:<28} | {:<16} | {:>7.2} | {:>12.3} | {:>11.3} | {:>14.4e} | {:>5} |\n",
                p.tag,
                p.spec,
                p.label,
                100.0 * p.accuracy,
                p.energy_pj / 1e3,
                p.latency_ns / 1e3,
                p.edp_pj_ns,
                if p.on_front { "*" } else { "" },
            ));
        }
        let front = self.front();
        s.push_str(&format!(
            "\npareto front ({} of {} points): {}\n",
            front.len(),
            self.points.len(),
            front
                .iter()
                .map(|p| format!("{} {}", p.tag, p.spec))
                .collect::<Vec<_>>()
                .join("  ->  ")
        ));
        // the paper's headline compares *stochastic MTJ* processing to the
        // FP ADC (not whatever baseline happens to be cheapest, e.g. the
        // accuracy-destroying 1b-SA) — points are EDP-ascending, so the
        // first stox cell is the cheapest MTJ design point and the last
        // ideal cell is the HPFA-class corner of the matrix
        let mtj = self.points.iter().find(|p| p.spec.starts_with("stox"));
        let fp = self.points.iter().rev().find(|p| p.spec == "ideal");
        if let (Some(mtj), Some(fp)) = (mtj, fp) {
            if mtj.edp_pj_ns > 0.0 {
                s.push_str(&format!(
                    "EDP gain of stochastic MTJ '{} {}' over full-precision ADC '{} {}': {:.1}x (paper: up to 130x)\n",
                    mtj.tag,
                    mtj.spec,
                    fp.tag,
                    fp.spec,
                    fp.edp_pj_ns / mtj.edp_pj_ns
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn mini_specs() -> Vec<PsConverterSpec> {
        vec![
            "ideal".parse().unwrap(),
            "sa".parse().unwrap(),
            "stox:alpha=4,samples=1".parse().unwrap(),
            "stox:alpha=4,samples=4".parse().unwrap(),
            "quant:bits=4".parse().unwrap(),
        ]
    }

    fn mini_sweep(threads: usize) -> SweepResult {
        let cfg = StoxConfig::default();
        let gw = GoldenWorkload::new(cfg, 24, 7).unwrap();
        run_sweep(
            &mini_specs(),
            &cfg,
            &zoo::resnet20_cifar(),
            "resnet20_cifar",
            7,
            threads,
            |spec| Ok(gw.accuracy(spec.build(&cfg)?.as_ref())),
        )
        .unwrap()
    }

    #[test]
    fn parse_grid_values_and_ranges() {
        assert_eq!(parse_grid("1,2,4..6").unwrap(), vec![1, 2, 4, 5, 6]);
        assert_eq!(parse_grid(" 8 ").unwrap(), vec![8]);
        assert!(parse_grid("").is_err());
        assert!(parse_grid("3..1").is_err());
        assert!(parse_grid("x").is_err());
    }

    #[test]
    fn default_grid_covers_registry_and_dedupes() {
        let cfg = StoxConfig::default();
        let specs = default_grid(&cfg, &[1, 2, 4], &[1, 4, 8]);
        let strs: Vec<String> = specs.iter().map(|s| s.to_string()).collect();
        for name in default_registry().names() {
            assert!(
                specs.iter().any(|s| s.mode_name() == name),
                "grid missing registry mode {name}"
            );
        }
        let mut dedup = strs.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), strs.len(), "duplicate specs in grid");
        // every grid spec builds through the registry
        for s in &specs {
            s.build(&cfg).unwrap();
        }
    }

    #[test]
    fn pareto_flags_simple_front() {
        // (acc, edp): the front is the high-acc/low-edp staircase
        let pts = [
            (1.0, 100.0), // on front (best acc)
            (0.9, 10.0),  // on front
            (0.8, 50.0),  // dominated by (0.9, 10)
            (0.5, 1.0),   // on front (cheapest)
            (0.5, 1.0),   // duplicate — only first kept
        ];
        let f = pareto_front_flags(&pts);
        assert_eq!(f, vec![true, true, false, true, false]);
    }

    #[test]
    fn golden_workload_ideal_scores_one() {
        let cfg = StoxConfig::default();
        let gw = GoldenWorkload::new(cfg, 16, 3).unwrap();
        let ideal = PsConverterSpec::IdealAdc.build(&cfg).unwrap();
        assert_eq!(gw.accuracy(ideal.as_ref()), 1.0);
        // lossy 1-bit readout must not be scored as lossless
        let sa = PsConverterSpec::SenseAmp.build(&cfg).unwrap();
        assert!(gw.accuracy(sa.as_ref()) <= 1.0);
    }

    #[test]
    fn sweep_is_thread_count_invariant() {
        let a = mini_sweep(1);
        let b = mini_sweep(8);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn parse_precision_tags_dedupes_and_validates() {
        let base = StoxConfig::default();
        let tags = parse_precision_tags("4w4a4bs, 8w8a4bs,4w4a4bs", &base).unwrap();
        assert_eq!(tags.len(), 2);
        assert_eq!(tags[0].tag(), "4w4a4bs");
        assert_eq!(tags[1].tag(), "8w8a4bs");
        assert!(parse_precision_tags("", &base).is_err());
        assert!(parse_precision_tags("6w4a4bs", &base).is_err());
    }

    #[test]
    fn matrix_sweep_crosses_tags_and_specs() {
        let base = StoxConfig::default();
        let tags = parse_precision_tags("4w4a4bs,8w8a4bs", &base).unwrap();
        let gws: Vec<GoldenWorkload> = tags
            .iter()
            .map(|c| GoldenWorkload::new(*c, 16, 5).unwrap())
            .collect();
        let grid: Vec<(StoxConfig, Vec<PsConverterSpec>)> =
            tags.iter().map(|c| (*c, mini_specs())).collect();
        let r = run_matrix_sweep(
            &grid,
            &zoo::resnet20_cifar(),
            "resnet20_cifar",
            5,
            4,
            |ti, spec| Ok(gws[ti].accuracy(spec.build(gws[ti].cfg())?.as_ref())),
        )
        .unwrap();
        assert_eq!(r.points.len(), 2 * mini_specs().len());
        // every cell is addressable and the tags really differ in cost
        let lo = r.point_at("4w4a4bs", "ideal").unwrap();
        let hi = r.point_at("8w8a4bs", "ideal").unwrap();
        assert!(
            lo.energy_pj < hi.energy_pj,
            "4w4a must be cheaper than 8w8a at the same converter"
        );
        // the single joint front spans the matrix
        assert!(!r.front().is_empty());
        // duplicate (tag, spec) cells are dropped
        let mut dup_grid = grid.clone();
        dup_grid.push((tags[0], mini_specs()));
        let r2 = run_matrix_sweep(
            &dup_grid,
            &zoo::resnet20_cifar(),
            "resnet20_cifar",
            5,
            2,
            |ti, spec| Ok(gws[ti.min(1)].accuracy(spec.build(gws[ti.min(1)].cfg())?.as_ref())),
        )
        .unwrap();
        assert_eq!(r2.points.len(), r.points.len());
    }

    /// The EDP cross-check acceptance bound: on the golden workload the
    /// counter-priced measured energy of every exact (non-stochastic-
    /// cost) converter must sit within 1% of the analytic prediction —
    /// in fact the action counts agree exactly, so the error is ~0.
    #[test]
    fn measured_energy_cross_checks_analytic_model() {
        let cfg = StoxConfig::default();
        let mut gw = GoldenWorkload::new(cfg, 8, 7).unwrap();
        let costs = ComponentCosts::default();
        for s in ["ideal", "quant:bits=8", "sparse:bits=4", "sa"] {
            let spec: PsConverterSpec = s.parse().unwrap();
            let cell = gw
                .measure_energy(&spec, &costs)
                .unwrap()
                .expect("default config runs the integer kernel");
            assert!(!cell.stochastic_cost, "{s} is an exact-cost converter");
            assert!(
                cell.rel_err <= 0.01,
                "{s}: rel err {} (predicted {} pJ, measured {} pJ)",
                cell.rel_err,
                cell.predicted_pj,
                cell.measured_pj
            );
        }
        // MTJ cells are flagged stochastic-cost (exempt from the strict
        // bound) — but logical draw counting makes them land exactly too
        for s in ["stox:alpha=4,samples=2", "inhomo:alpha=4,base=1,extra=3"] {
            let spec: PsConverterSpec = s.parse().unwrap();
            let cell = gw.measure_energy(&spec, &costs).unwrap().unwrap();
            assert!(cell.stochastic_cost, "{s} carries an MTJ cost key");
            assert!(
                cell.rel_err <= 0.05,
                "{s}: rel err {} (predicted {} pJ, measured {} pJ)",
                cell.rel_err,
                cell.predicted_pj,
                cell.measured_pj
            );
        }
        // the grid driver covers the same cells and renders
        let grid = [(cfg, vec!["ideal".parse().unwrap(), "sa".parse().unwrap()])];
        let cells = measure_grid(&grid, 4, 7).unwrap();
        assert_eq!(cells.len(), 2);
        let table = render_measured_table(&cells);
        assert!(table.contains("rel err") && table.contains("ideal"));
        assert!(cells[0].to_json().to_string().contains("predicted_pj"));
    }

    #[test]
    fn sweep_front_has_mtj_dominating_fp_adc_on_edp() {
        let r = mini_sweep(4);
        let mtj = r.point("stox:alpha=4,samples=1").unwrap();
        let fp = r.point("ideal").unwrap();
        assert!(
            mtj.edp_pj_ns < fp.edp_pj_ns,
            "stochastic MTJ must beat the FP ADC on EDP ({} vs {})",
            mtj.edp_pj_ns,
            fp.edp_pj_ns
        );
        assert!(!r.front().is_empty());
        assert_eq!(fp.accuracy, 1.0, "ideal readout defines the labels");
        // artifacts render
        assert!(r.to_csv().lines().count() == r.points.len() + 1);
        assert!(r.render_table().contains("pareto front"));
    }
}
