//! Design-level energy / latency / area / EDP rollups — the model behind
//! Fig. 9 and the paper's headline 16×/8×/10× and 24–130× EDP claims.

use super::components::{ComponentCosts, PsProcessing};
use super::mapper::{map_layer, LayerShape, MappedLayer};
use super::pipeline::PipelineModel;
use crate::imc::{PsConvert, PsConverterSpec, StoxConfig};
use std::collections::HashMap;

/// A full IMC design point: precision mapping + PS processing choice.
#[derive(Debug, Clone)]
pub struct DesignConfig {
    pub name: String,
    pub stox: StoxConfig,
    /// PS processing for ordinary layers
    pub ps: PsProcessing,
    /// PS processing for the first conv layer (HPF → FP ADC; QF → MTJ×8)
    pub first_layer_ps: PsProcessing,
    /// physical columns per crossbar
    pub c_arr: usize,
    /// bits per memory cell (cells per weight = w_slice_bits/bits_per_cell
    /// is already folded into the mapper via n_slices; this picks the cell
    /// read energy row of Table 2)
    pub bits_per_cell: u32,
    /// per-layer sampling override (Mix scheme): layer name → samples
    pub layer_samples: HashMap<String, u32>,
    /// fraction of analog events that actually fire (SFA's sparsity-aware
    /// baseline skips zero-activation work); 1.0 = dense
    pub activity: f64,
}

impl DesignConfig {
    /// Paper baseline "HPFA": 8-bit operands, 2 bits/cell, full-precision
    /// SAR ADC shared by 16 columns (column-MUX, §1).
    pub fn hpfa() -> Self {
        Self {
            name: "HPFA".into(),
            stox: StoxConfig {
                a_bits: 8,
                w_bits: 8,
                a_stream_bits: 1,
                w_slice_bits: 2,
                r_arr: 256,
                n_samples: 1,
                alpha: 0.0,
            },
            ps: PsProcessing::AdcFullPrecision { share: 16 },
            first_layer_ps: PsProcessing::AdcFullPrecision { share: 16 },
            c_arr: 128,
            bits_per_cell: 2,
            layer_samples: HashMap::new(),
            activity: 1.0,
        }
    }

    /// Sparse baseline "SFA": (full precision − 1)-bit ADC.
    pub fn sfa() -> Self {
        Self {
            name: "SFA".into(),
            ps: PsProcessing::AdcSparse { share: 16 },
            first_layer_ps: PsProcessing::AdcSparse { share: 16 },
            // sparsity-aware baseline: ~50% of activations are zero and
            // their conversions/reads are skipped (§2.3 related work)
            activity: 0.5,
            ..Self::hpfa()
        }
    }

    /// StoX design point: `tag`-precision operands, MTJ converters with
    /// `samples` reads; `qf` selects the stochastic (8-sample) first layer.
    pub fn stox(tag_cfg: StoxConfig, samples: u32, qf: bool) -> Self {
        let first = if qf {
            PsProcessing::StochasticMtj { samples: 8 }
        } else {
            PsProcessing::AdcFullPrecision { share: 16 }
        };
        Self {
            name: format!(
                "StoX-{}-{}{}",
                tag_cfg.tag(),
                samples,
                if qf { "QF" } else { "HPF" }
            ),
            stox: tag_cfg,
            ps: PsProcessing::StochasticMtj { samples },
            first_layer_ps: first,
            c_arr: 128,
            bits_per_cell: tag_cfg.w_slice_bits.min(2),
            layer_samples: HashMap::new(),
            activity: 1.0,
        }
    }

    /// Design point derived from converter *specs* through the
    /// [`PsConvert::cost_key`] hook — the cost model charges exactly the
    /// component rows of the converters that actually run on the
    /// functional path, so serving metrics and Fig. 9 rollups stay in
    /// lockstep with whatever the registry built (including converters
    /// the closed constructors above never knew about).
    ///
    /// `stox` is validated first: this is the constructor the design-matrix
    /// sweep calls once per `(precision tag, converter spec)` cell, so a
    /// malformed tag config (indivisible slice/stream widths) fails here
    /// with the reason instead of producing a nonsense rollup.
    pub fn from_specs(
        stox: StoxConfig,
        body: &PsConverterSpec,
        first: &PsConverterSpec,
    ) -> crate::Result<Self> {
        stox.validate()?;
        let ps = body.build(&stox)?.cost_key();
        let first_layer_ps = first.build(&stox)?.cost_key();
        Ok(Self {
            name: format!("StoX-{}-{body}/{first}", stox.tag()),
            stox,
            ps,
            first_layer_ps,
            c_arr: 128,
            bits_per_cell: stox.w_slice_bits.min(2),
            layer_samples: HashMap::new(),
            activity: 1.0,
        })
    }

    /// Mix variant: base 1-sample MTJ with per-layer overrides.
    pub fn stox_mix(
        tag_cfg: StoxConfig,
        qf: bool,
        overrides: &[(&str, u32)],
    ) -> Self {
        let mut d = Self::stox(tag_cfg, 1, qf);
        d.name = format!(
            "StoX-{}-Mix{}",
            tag_cfg.tag(),
            if qf { "QF" } else { "HPF" }
        );
        d.layer_samples = overrides
            .iter()
            .map(|(n, s)| (n.to_string(), *s))
            .collect();
        d
    }

    fn ps_for(&self, layer: &LayerShape, idx: usize) -> PsProcessing {
        if idx == 0 || !layer.stochastic {
            return self.first_layer_ps;
        }
        if let Some(&s) = self.layer_samples.get(&layer.name) {
            if let PsProcessing::StochasticMtj { .. } = self.ps {
                return PsProcessing::StochasticMtj { samples: s };
            }
        }
        self.ps
    }
}

/// Per-design evaluation result (one bar group of Fig. 9a).
#[derive(Debug, Clone)]
pub struct DesignReport {
    pub name: String,
    pub energy_pj: f64,
    pub latency_ns: f64,
    pub area_um2: f64,
    pub edp_pj_ns: f64,
    pub conversions: u64,
    pub xbars: usize,
    pub per_layer: Vec<LayerReport>,
}

#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub energy_pj: f64,
    pub latency_ns: f64,
    pub area_um2: f64,
    pub conversions: u64,
}

/// Evaluate one layer under a design.
fn eval_layer(
    costs: &ComponentCosts,
    pipe: &PipelineModel,
    design: &DesignConfig,
    shape: &LayerShape,
    idx: usize,
) -> (MappedLayer, LayerReport, PsProcessing) {
    let ps = design.ps_for(shape, idx);
    let mapped = map_layer(shape, &design.stox, design.c_arr);

    let act = design.activity;
    let e_dac = mapped.dac_actions as f64 * costs.dac_energy_pj * act;
    let e_cell = mapped.cell_actions as f64
        * costs.cell_energy_pj(design.bits_per_cell)
        * act;
    let e_ps = mapped.conversions as f64 * costs.ps_energy_pj(ps) * act;
    let e_sna = mapped.sna_actions as f64 * costs.sna_energy_pj * act;
    let e_io = mapped.io_actions as f64 * costs.io_energy_pj * act;
    let energy = e_dac + e_cell + e_ps + e_sna + e_io;

    let latency = pipe.layer_latency_ns(&mapped, ps);

    let a_cells = mapped.xbars as f64
        * (design.stox.r_arr * design.c_arr) as f64
        * costs.cell_area_um2;
    let a_dac = mapped.xbars as f64 * design.stox.r_arr as f64 * costs.dac_area_um2;
    let a_ps =
        mapped.converter_sites as f64 * costs.ps_area_per_column_um2(ps);
    let a_sna = mapped.xbars as f64 * costs.sna_area_um2;
    let a_overhead = mapped.xbars as f64 * costs.tile_overhead_um2;
    let area = a_cells + a_dac + a_ps + a_sna + a_overhead;

    let report = LayerReport {
        name: shape.name.clone(),
        energy_pj: energy,
        latency_ns: latency,
        area_um2: area,
        conversions: mapped.conversions,
    };
    (mapped, report, ps)
}

/// Evaluate a network under a design point.
pub fn evaluate_design(
    costs: &ComponentCosts,
    design: &DesignConfig,
    layers: &[LayerShape],
) -> DesignReport {
    let pipe = PipelineModel { costs: *costs, ..Default::default() };
    let mut per_layer = Vec::with_capacity(layers.len());
    let (mut e, mut t, mut a, mut conv, mut xb) = (0.0, 0.0, 0.0, 0u64, 0usize);
    for (idx, shape) in layers.iter().enumerate() {
        let (mapped, rep, ps) = eval_layer(costs, &pipe, design, shape, idx);
        let samples = ps.samples() as u64;
        e += rep.energy_pj;
        t += rep.latency_ns;
        a += rep.area_um2;
        conv += rep.conversions * samples;
        xb += mapped.xbars;
        per_layer.push(rep);
    }
    DesignReport {
        name: design.name.clone(),
        energy_pj: e,
        latency_ns: t,
        area_um2: a,
        edp_pj_ns: e * t,
        conversions: conv,
        xbars: xb,
        per_layer,
    }
}

/// Convenience: evaluate several designs and return (report, edp-vs-first).
pub fn evaluate_network(
    costs: &ComponentCosts,
    designs: &[DesignConfig],
    layers: &[LayerShape],
) -> Vec<(DesignReport, f64)> {
    let reports: Vec<DesignReport> = designs
        .iter()
        .map(|d| evaluate_design(costs, d, layers))
        .collect();
    let base_edp = reports
        .first()
        .map(|r| r.edp_pj_ns)
        .unwrap_or(1.0);
    reports
        .into_iter()
        .map(|r| {
            let gain = base_edp / r.edp_pj_ns;
            (r, gain)
        })
        .collect()
}

/// Hardware-counter action totals read back from the telemetry plane:
/// the `imc.l*.<tag>.*` kernel counters of a
/// [`crate::obs::CounterRegistry`] snapshot, summed across layer scopes.
/// These are *measured* counts — what the functional kernel actually
/// did — as opposed to the [`map_layer`] analytic predictions; the two
/// agree exactly whenever the kernel performs the actions the mapper
/// charges (the `sweep --measured` cross-check).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterTotals {
    /// PS conversion events (`conversions`)
    pub conversions: u64,
    /// DAC row-drive actions (`dac_actions`)
    pub dac_actions: u64,
    /// crossbar cell read actions (`cell_actions`)
    pub cell_actions: u64,
    /// converted output elements written off-tile (`out_io`)
    pub out_io: u64,
    /// individual MTJ reads (`mtj_draws`; 0 for ADC-class converters)
    pub mtj_draws: u64,
}

impl CounterTotals {
    /// Sum the kernel counters of every `imc.` layer scope in a
    /// name-sorted snapshot ([`crate::obs::CounterRegistry::snapshot`]).
    /// Non-`imc.` counters (e.g. the host-dependent `simd.select.*`) are
    /// ignored.
    pub fn from_snapshot(snap: &[(String, u64)]) -> Self {
        let mut t = Self::default();
        for (name, v) in snap {
            if !name.starts_with("imc.") {
                continue;
            }
            match name.rsplit('.').next() {
                Some("conversions") => t.conversions += v,
                Some("dac_actions") => t.dac_actions += v,
                Some("cell_actions") => t.cell_actions += v,
                Some("out_io") => t.out_io += v,
                Some("mtj_draws") => t.mtj_draws += v,
                _ => {}
            }
        }
        t
    }
}

/// Energy priced from *measured* hardware counters through the same
/// Table 2 cost rows as [`evaluate_design`] — the measured half of the
/// EDP cross-check behind `stox-cli sweep --measured`.
#[derive(Debug, Clone, Copy)]
pub struct MeasuredEnergy {
    /// total per-inference energy (pJ) — the sum of the components below
    pub energy_pj: f64,
    pub e_dac_pj: f64,
    pub e_cell_pj: f64,
    pub e_ps_pj: f64,
    pub e_sna_pj: f64,
    pub e_io_pj: f64,
}

impl MeasuredEnergy {
    /// Price counter totals per inference under `design`'s component
    /// choices.  MTJ-class converters are charged per *measured read*
    /// (`mtj_draws × E_MTJ`); ADC-class converters (which draw nothing)
    /// per conversion event through [`ComponentCosts::ps_energy_pj`].
    /// Assumes the sweep's uniform design point — one converter on every
    /// layer — since the totals are summed across layer scopes.
    pub fn from_counters(
        costs: &ComponentCosts,
        design: &DesignConfig,
        totals: &CounterTotals,
        inferences: u64,
    ) -> crate::Result<Self> {
        anyhow::ensure!(inferences > 0, "measured energy needs >= 1 inference");
        let per = 1.0 / inferences as f64;
        let e_dac = totals.dac_actions as f64 * costs.dac_energy_pj * per;
        let e_cell =
            totals.cell_actions as f64 * costs.cell_energy_pj(design.bits_per_cell) * per;
        let e_ps = if totals.mtj_draws > 0 {
            totals.mtj_draws as f64 * costs.mtj_energy_pj * per
        } else {
            totals.conversions as f64 * costs.ps_energy_pj(design.ps) * per
        };
        let e_sna = totals.conversions as f64 * costs.sna_energy_pj * per;
        let e_io = (totals.dac_actions + totals.out_io) as f64 * costs.io_energy_pj * per;
        Ok(Self {
            energy_pj: e_dac + e_cell + e_ps + e_sna + e_io,
            e_dac_pj: e_dac,
            e_cell_pj: e_cell,
            e_ps_pj: e_ps,
            e_sna_pj: e_sna,
            e_io_pj: e_io,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn costs() -> ComponentCosts {
        ComponentCosts::default()
    }

    #[test]
    fn stox_beats_hpfa_on_all_axes() {
        let layers = zoo::resnet20_cifar();
        let hpfa = evaluate_design(&costs(), &DesignConfig::hpfa(), &layers);
        let stox = evaluate_design(
            &costs(),
            &DesignConfig::stox(StoxConfig::default(), 1, true),
            &layers,
        );
        assert!(stox.energy_pj < hpfa.energy_pj);
        assert!(stox.latency_ns < hpfa.latency_ns);
        assert!(stox.area_um2 < hpfa.area_um2);
    }

    #[test]
    fn edp_gains_in_paper_band() {
        // Paper: 130x vs HPFA, 24x vs SFA (up to).
        let layers = zoo::resnet20_cifar();
        let hpfa = evaluate_design(&costs(), &DesignConfig::hpfa(), &layers);
        let sfa = evaluate_design(&costs(), &DesignConfig::sfa(), &layers);
        let stox = evaluate_design(
            &costs(),
            &DesignConfig::stox(StoxConfig::default(), 1, true),
            &layers,
        );
        let g_hpfa = hpfa.edp_pj_ns / stox.edp_pj_ns;
        let g_sfa = sfa.edp_pj_ns / stox.edp_pj_ns;
        assert!(g_hpfa > 20.0, "EDP vs HPFA {g_hpfa:.1}x");
        assert!(g_sfa > 5.0, "EDP vs SFA {g_sfa:.1}x");
        assert!(g_hpfa > g_sfa, "FP baseline must be weaker");
    }

    #[test]
    fn multisampling_costs_energy_and_latency() {
        let layers = zoo::resnet20_cifar();
        let s1 = evaluate_design(
            &costs(),
            &DesignConfig::stox(StoxConfig::default(), 1, true),
            &layers,
        );
        let s8 = evaluate_design(
            &costs(),
            &DesignConfig::stox(StoxConfig::default(), 8, true),
            &layers,
        );
        assert!(s8.energy_pj > s1.energy_pj);
        assert!(s8.latency_ns >= s1.latency_ns);
        assert!(s8.edp_pj_ns > s1.edp_pj_ns);
    }

    #[test]
    fn mix_between_1_and_4_samples() {
        let layers = zoo::resnet20_cifar();
        let mk = |s| {
            evaluate_design(
                &costs(),
                &DesignConfig::stox(StoxConfig::default(), s, true),
                &layers,
            )
        };
        let overrides: Vec<(&str, u32)> =
            vec![("s0b0c1", 4), ("s0b0c2", 4), ("s0b1c1", 2), ("s0b1c2", 2)];
        let mix = evaluate_design(
            &costs(),
            &DesignConfig::stox_mix(StoxConfig::default(), true, &overrides),
            &layers,
        );
        let (s1, s4) = (mk(1), mk(4));
        assert!(mix.conversions > s1.conversions);
        assert!(mix.conversions < s4.conversions);
        // Paper: Mix only slightly increases conversions vs 1-sample
        let increase = mix.conversions as f64 / s1.conversions as f64;
        assert!(increase < 1.6, "Mix conversion increase {increase}");
    }

    #[test]
    fn reduced_precision_contributes() {
        // 4w4a vs 8w8a with the same MTJ converter: fewer streams/slices.
        let layers = zoo::resnet20_cifar();
        let lo = evaluate_design(
            &costs(),
            &DesignConfig::stox(StoxConfig::default(), 1, true),
            &layers,
        );
        let hi_cfg = StoxConfig {
            a_bits: 8,
            w_bits: 8,
            w_slice_bits: 2,
            ..StoxConfig::default()
        };
        let hi = evaluate_design(
            &costs(),
            &DesignConfig::stox(hi_cfg, 1, true),
            &layers,
        );
        assert!(lo.energy_pj < hi.energy_pj);
    }

    #[test]
    fn design_from_specs_matches_legacy_constructor() {
        // the cost_key hook must reproduce what DesignConfig::stox charged
        let legacy = DesignConfig::stox(StoxConfig::default(), 4, true);
        let spec = DesignConfig::from_specs(
            StoxConfig::default(),
            &"stox:alpha=4,samples=4".parse().unwrap(),
            &"stox:alpha=4,samples=8".parse().unwrap(),
        )
        .unwrap();
        assert_eq!(spec.ps, legacy.ps);
        assert_eq!(spec.first_layer_ps, legacy.first_layer_ps);
        let layers = zoo::resnet20_cifar();
        let a = evaluate_design(&costs(), &legacy, &layers);
        let b = evaluate_design(&costs(), &spec, &layers);
        assert_eq!(a.energy_pj, b.energy_pj);
        assert_eq!(a.latency_ns, b.latency_ns);
    }

    #[test]
    fn sparse_adc_spec_costs_between_sa_and_fp_adc() {
        let layers = zoo::resnet20_cifar();
        let mk = |body: &str| {
            evaluate_design(
                &costs(),
                &DesignConfig::from_specs(
                    StoxConfig::default(),
                    &body.parse().unwrap(),
                    &"ideal".parse().unwrap(),
                )
                .unwrap(),
                &layers,
            )
        };
        let sa = mk("sa");
        let sparse = mk("sparse:bits=4");
        let fp = mk("quant:bits=8");
        assert!(sa.energy_pj < sparse.energy_pj, "1b-SA under sparse ADC");
        assert!(sparse.energy_pj < fp.energy_pj, "sparse ADC under FP ADC");
    }

    /// The fractional-samples upgrade (carried since PR 1) changes the
    /// sweep's EDP column only for `inhomo:*` specs: every other builtin
    /// keeps its whole-sample cost key bit-for-bit, while inhomo charges
    /// its exact 2.5-sample mean instead of the mean-rounded 3.
    #[test]
    fn fractional_samples_change_only_inhomo_edp() {
        let cfg = StoxConfig::default();
        let layers = zoo::resnet20_cifar();
        for s in [
            "ideal",
            "quant:bits=8",
            "sparse:bits=4",
            "sa",
            "expected:alpha=4",
            "stox:alpha=4,samples=1",
            "stox:alpha=4,samples=4",
        ] {
            let spec: PsConverterSpec = s.parse().unwrap();
            let key = spec.build(&cfg).unwrap().cost_key();
            assert!(
                !matches!(key, PsProcessing::StochasticMtjFrac { .. }),
                "{s}: non-inhomo cost keys must be unchanged"
            );
        }
        let spec: PsConverterSpec = "inhomo:alpha=4,base=1,extra=3".parse().unwrap();
        assert_eq!(
            spec.build(&cfg).unwrap().cost_key(),
            PsProcessing::StochasticMtjFrac { millisamples: 2500 },
            "4w4a4bs inhomo mean is exactly 2.5 reads"
        );
        let exact = evaluate_design(
            &costs(),
            &DesignConfig::from_specs(cfg, &spec, &spec).unwrap(),
            &layers,
        );
        // the legacy mean-rounded design point (what cost_key charged
        // before the fractional variant)
        let mut legacy = DesignConfig::from_specs(cfg, &spec, &spec).unwrap();
        legacy.ps = PsProcessing::StochasticMtj { samples: 3 };
        legacy.first_layer_ps = PsProcessing::StochasticMtj { samples: 3 };
        let rounded = evaluate_design(&costs(), &legacy, &layers);
        assert!(
            exact.edp_pj_ns < rounded.edp_pj_ns,
            "exact 2.5-sample EDP {} must drop below the rounded 3-sample {}",
            exact.edp_pj_ns,
            rounded.edp_pj_ns
        );
        // and stays strictly between the 2- and 3-sample whole charges
        legacy.ps = PsProcessing::StochasticMtj { samples: 2 };
        legacy.first_layer_ps = PsProcessing::StochasticMtj { samples: 2 };
        let two = evaluate_design(&costs(), &legacy, &layers);
        assert!(two.energy_pj < exact.energy_pj && exact.energy_pj < rounded.energy_pj);
        // conversions still count whole reads (mean rounds half-up to 3)
        assert_eq!(exact.conversions, rounded.conversions);
    }

    #[test]
    fn inhomogeneous_spec_costs_between_base_and_max_sampling() {
        // 4w4a1bs → a 4×4 (stream × slice) grid, base 1 .. 1+3 samples
        let cfg = StoxConfig { w_slice_bits: 1, ..StoxConfig::default() };
        let layers = zoo::resnet20_cifar();
        let mk = |body: &str| {
            evaluate_design(
                &costs(),
                &DesignConfig::from_specs(
                    cfg,
                    &body.parse().unwrap(),
                    &"stox:samples=8".parse().unwrap(),
                )
                .unwrap(),
                &layers,
            )
        };
        let lo = mk("stox:samples=1");
        let hi = mk("stox:samples=4");
        let mix = mk("inhomo:base=1,extra=3");
        assert!(mix.energy_pj > lo.energy_pj, "inhomo above 1-sample");
        assert!(mix.energy_pj < hi.energy_pj, "inhomo below max-sample");
    }

    /// Feeding the mapper's own analytic action counts back through
    /// [`MeasuredEnergy::from_counters`] must reproduce
    /// [`evaluate_design`]'s energy bit-for-bit — the identity behind the
    /// `sweep --measured` cross-check (any kernel/mapper divergence shows
    /// up as a nonzero relative error there).
    #[test]
    fn counter_priced_energy_matches_analytic_on_mapper_counts() {
        let layers = vec![LayerShape::conv("l0", 3, 16, 32, 8, true)];
        for (body, first) in [
            ("stox:alpha=4,samples=2", "stox:alpha=4,samples=2"),
            ("quant:bits=8", "quant:bits=8"),
            ("sa", "sa"),
        ] {
            let design = DesignConfig::from_specs(
                StoxConfig::default(),
                &body.parse().unwrap(),
                &first.parse().unwrap(),
            )
            .unwrap();
            let predicted = evaluate_design(&costs(), &design, &layers).energy_pj;
            let mapped = map_layer(&layers[0], &design.stox, design.c_arr);
            let draws = match design.ps {
                PsProcessing::StochasticMtj { samples } => {
                    mapped.conversions * samples as u64
                }
                _ => 0,
            };
            let totals = CounterTotals {
                conversions: mapped.conversions,
                dac_actions: mapped.dac_actions,
                cell_actions: mapped.cell_actions,
                out_io: mapped.io_actions - mapped.dac_actions,
                mtj_draws: draws,
            };
            let measured =
                MeasuredEnergy::from_counters(&costs(), &design, &totals, 1).unwrap();
            assert!(
                (measured.energy_pj - predicted).abs() <= 1e-9 * predicted,
                "{body}: measured {} vs predicted {predicted}",
                measured.energy_pj
            );
        }
        assert!(
            MeasuredEnergy::from_counters(
                &costs(),
                &DesignConfig::hpfa(),
                &CounterTotals::default(),
                0
            )
            .is_err(),
            "zero inferences must fail loudly"
        );
    }

    #[test]
    fn counter_totals_sum_layer_scopes_and_skip_foreign_keys() {
        let snap = vec![
            ("imc.l00.4w4a4bs.conversions".to_string(), 10u64),
            ("imc.l01.4w4a4bs.conversions".to_string(), 5),
            ("imc.l00.4w4a4bs.dac_actions".to_string(), 7),
            ("imc.l00.4w4a4bs.mtj_draws".to_string(), 20),
            ("imc.l00.4w4a4bs.macs".to_string(), 999), // not an energy row
            ("simd.select.scalar".to_string(), 1),     // host counter: ignored
        ];
        let t = CounterTotals::from_snapshot(&snap);
        assert_eq!(t.conversions, 15);
        assert_eq!(t.dac_actions, 7);
        assert_eq!(t.mtj_draws, 20);
        assert_eq!(t.cell_actions, 0);
        assert_eq!(t.out_io, 0);
    }

    #[test]
    fn hpf_first_layer_dominates_low_precision_stox() {
        // The motivation for QF: with everything else stochastic, an
        // FP-ADC first layer is a large energy fraction.
        let layers = zoo::resnet20_cifar();
        let hpf = evaluate_design(
            &costs(),
            &DesignConfig::stox(StoxConfig::default(), 1, false),
            &layers,
        );
        let qf = evaluate_design(
            &costs(),
            &DesignConfig::stox(StoxConfig::default(), 1, true),
            &layers,
        );
        assert!(hpf.energy_pj > qf.energy_pj);
        let first_share = hpf.per_layer[0].energy_pj / hpf.energy_pj;
        assert!(first_share > 0.05, "conv1 share {first_share}");
    }
}
