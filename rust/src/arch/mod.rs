//! ISAAC-like IMC architecture accounting (paper §4.3, Figs. 6, 8, 9).
//!
//! The paper evaluates hardware efficiency with Accelergy/Timeloop-style
//! component-level accounting: per-action energies and per-instance areas
//! (Table 2) rolled up over the number of actions a workload induces, plus
//! a pipeline model for latency (Fig. 8).  This module implements exactly
//! that accounting:
//!
//! * [`components`] — the Table 2 cost database;
//! * [`mapper`] — DNN layer → crossbar instances / action counts
//!   (Algorithm 1's `N_arrs`, slices, streams, conversions);
//! * [`pipeline`] — stage-time model: column-shared ADC readout vs
//!   all-column-parallel MTJ conversion (Fig. 8);
//! * [`energy`] — per-layer and per-network energy/latency/area/EDP for a
//!   design configuration (HPFA / SFA / StoX / Mix), behind Fig. 9;
//! * [`sweep`] — registry-driven accuracy × energy Pareto sweep over all
//!   PS-converter specs (`stox-cli sweep`, the Fig. 9 trade-off front);
//! * [`tile`] — chip→tile→IMA→crossbar hierarchy instance counting.

pub mod components;
pub mod energy;
pub mod mapper;
pub mod pipeline;
pub mod sweep;
pub mod tile;

pub use components::{ComponentCosts, PsProcessing};
pub use energy::{DesignConfig, DesignReport, evaluate_design, evaluate_network};
pub use mapper::{LayerShape, MappedLayer};
pub use pipeline::PipelineModel;
pub use sweep::{
    default_grid, pareto_front_flags, parse_grid, run_sweep, GoldenWorkload, SweepPoint,
    SweepResult,
};
