//! DNN layer → crossbar mapping (Algorithm 1's partitioning) and
//! per-inference action counting.
//!
//! A conv layer of kernel K_h×K_w, C_in inputs and C_out outputs over
//! H_out×W_out positions becomes an MVM with `M = K_h·K_w·C_in` rows and
//! `N = C_out` columns executed `P = H_out·W_out` times.  The row axis is
//! split into `N_arrs = ceil(M/R_arr)` subarrays; weight bits into
//! `n_slices` physically separate slices (2 cells per weight, signed);
//! input bits stream over `n_streams` cycles; columns tile over crossbars
//! of `c_arr` physical columns.

use crate::imc::StoxConfig;

/// Shape of one DNN layer as seen by the mapper (also deserialized from
/// `artifacts/manifest.json`'s layer inventory).
#[derive(Debug, Clone)]
pub struct LayerShape {
    pub name: String,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub h_out: usize,
    pub w_out: usize,
    pub stride: usize,
    /// false → kept at high precision (HPF first layer / FC)
    pub stochastic: bool,
}

impl LayerShape {
    pub fn conv(
        name: &str,
        k: usize,
        cin: usize,
        cout: usize,
        h_out: usize,
        stochastic: bool,
    ) -> Self {
        Self {
            name: name.into(),
            kh: k,
            kw: k,
            cin,
            cout,
            h_out,
            w_out: h_out,
            stride: 1,
            stochastic,
        }
    }

    /// MVM row count M = K_h·K_w·C_in.
    pub fn m(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// Output positions per inference P = H_out·W_out.
    pub fn positions(&self) -> usize {
        self.h_out * self.w_out
    }

    /// Multiply-accumulates per inference (workload size metric).
    pub fn macs(&self) -> u64 {
        (self.m() * self.cout * self.positions()) as u64
    }
}

/// A layer mapped onto crossbars under a given `StoxConfig` + column width.
#[derive(Debug, Clone)]
pub struct MappedLayer {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub positions: usize,
    pub n_arrs: usize,
    pub n_slices: usize,
    pub n_streams: usize,
    /// column tiles: ceil(2N / c_arr) (2 cells per signed weight)
    pub col_tiles: usize,
    /// physical crossbar instances = n_arrs · n_slices · col_tiles
    pub xbars: usize,
    /// logical converter sites = columns × n_arrs × n_slices
    pub converter_sites: usize,
    // ---- per-inference action counts ----
    /// PS conversion events (before multi-sampling)
    pub conversions: u64,
    /// DAC row-drive actions
    pub dac_actions: u64,
    /// crossbar cell read actions
    pub cell_actions: u64,
    /// shift-and-add merge operations
    pub sna_actions: u64,
    /// tile I/O (eDRAM buffer / bus / router) activation accesses
    pub io_actions: u64,
}

/// Map one layer (physical columns per crossbar = `c_arr`).
pub fn map_layer(shape: &LayerShape, cfg: &StoxConfig, c_arr: usize) -> MappedLayer {
    let m = shape.m();
    let n = shape.cout;
    let p = shape.positions() as u64;
    let n_arrs = cfg.n_arrs(m);
    let n_slices = cfg.n_slices();
    let n_streams = cfg.n_streams();
    let col_tiles = (2 * n).div_ceil(c_arr).max(1);
    let xbars = n_arrs * n_slices * col_tiles;
    let converter_sites = n * n_arrs * n_slices;

    // Every (position, stream, slice, subarray, column) is one PS event.
    let conversions = p
        * n_streams as u64
        * n_slices as u64
        * n_arrs as u64
        * n as u64;
    // Every (position, stream) drives all M rows once.
    let dac_actions = p * n_streams as u64 * m as u64;
    // Every driven row reads 2·n_slices cells per column group; cell reads
    // scale with rows × columns touched.
    let cell_actions = p * n_streams as u64 * (m * 2 * n_slices) as u64;
    // One S&A merge per conversion event.
    let sna_actions = conversions;
    // Tile I/O: every streamed input bit is fetched once, every converted
    // output element written once per stream.
    let io_actions = dac_actions + p * n_streams as u64 * n as u64;

    MappedLayer {
        name: shape.name.clone(),
        m,
        n,
        positions: shape.positions(),
        n_arrs,
        n_slices,
        n_streams,
        col_tiles,
        xbars,
        converter_sites,
        conversions,
        dac_actions,
        cell_actions,
        sna_actions,
        io_actions,
    }
}

/// Map a whole network (only `stochastic` layers unless `include_all`).
pub fn map_network(
    layers: &[LayerShape],
    cfg: &StoxConfig,
    c_arr: usize,
) -> Vec<MappedLayer> {
    layers.iter().map(|l| map_layer(l, cfg, c_arr)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> LayerShape {
        LayerShape::conv("s1b0c1", 3, 64, 64, 16, true)
    }

    #[test]
    fn basic_mapping_counts() {
        let cfg = StoxConfig { r_arr: 256, w_slice_bits: 1, ..Default::default() };
        let m = map_layer(&shape(), &cfg, 128);
        assert_eq!(m.m, 576);
        assert_eq!(m.n_arrs, 3);
        assert_eq!(m.n_slices, 4);
        assert_eq!(m.n_streams, 4);
        assert_eq!(m.col_tiles, 1);
        assert_eq!(m.xbars, 12);
        // conversions: P·I·J·K·N = 256·4·4·3·64
        assert_eq!(m.conversions, 256 * 4 * 4 * 3 * 64);
        assert_eq!(m.dac_actions, 256 * 4 * 576);
    }

    #[test]
    fn paper_n_arrs_formula() {
        // ceil(K_h·K_w·C_in / R_arr)
        let cfg = StoxConfig { r_arr: 128, ..Default::default() };
        let l = LayerShape::conv("x", 3, 16, 32, 32, true);
        assert_eq!(map_layer(&l, &cfg, 128).n_arrs, (3 * 3 * 16usize).div_ceil(128));
    }

    #[test]
    fn column_tiling() {
        let cfg = StoxConfig::default();
        let wide = LayerShape::conv("w", 1, 64, 512, 7, true);
        let m = map_layer(&wide, &cfg, 128);
        assert_eq!(m.col_tiles, (2 * 512usize).div_ceil(128));
    }

    #[test]
    fn macs_metric() {
        let l = shape();
        assert_eq!(l.macs(), 576 * 64 * 256);
    }

    #[test]
    fn slicing_tradeoff() {
        // 1-bit slices: 4× the arrays but cheaper converters per paper's
        // N = log2(rows)+I+W-2 precision relation.
        let s1 = StoxConfig { w_slice_bits: 1, ..Default::default() };
        let s4 = StoxConfig { w_slice_bits: 4, ..Default::default() };
        let m1 = map_layer(&shape(), &s1, 128);
        let m4 = map_layer(&shape(), &s4, 128);
        assert_eq!(m1.xbars, 4 * m4.xbars);
        assert_eq!(m1.conversions, 4 * m4.conversions);
    }
}
