//! ISAAC-like tile hierarchy (Fig. 6): chip → tile → IMA → crossbar.
//!
//! The energy rollup in [`super::energy`] is hierarchy-agnostic (it counts
//! actions); this module assigns mapped crossbars to physical IMAs/tiles
//! for floorplan-level reporting and for the coordinator's tile scheduler.

use super::mapper::MappedLayer;

#[derive(Debug, Clone, Copy)]
pub struct TileGeometry {
    /// crossbars per in-situ multiply-accumulate unit
    pub xbars_per_ima: usize,
    /// IMAs per tile
    pub imas_per_tile: usize,
    /// shared eDRAM buffer per tile (KiB) — capacity check only
    pub edram_kib: usize,
}

impl Default for TileGeometry {
    /// ISAAC: 8 crossbars/IMA, 12 IMAs/tile, 64 KiB eDRAM.
    fn default() -> Self {
        Self { xbars_per_ima: 8, imas_per_tile: 12, edram_kib: 64 }
    }
}

/// Placement of one layer onto the hierarchy.
#[derive(Debug, Clone)]
pub struct Placement {
    pub layer: String,
    pub xbars: usize,
    pub imas: usize,
    pub tiles: usize,
    /// first tile index assigned to this layer
    pub tile_offset: usize,
}

/// A full-network floorplan.
#[derive(Debug, Clone)]
pub struct Floorplan {
    pub geometry: TileGeometry,
    pub placements: Vec<Placement>,
    pub total_tiles: usize,
    pub total_imas: usize,
    pub total_xbars: usize,
}

/// Greedy contiguous placement: each layer gets whole IMAs (weight-
/// stationary; a layer's crossbars never share an IMA with another layer,
/// mirroring ISAAC's replication unit).
pub fn place(layers: &[MappedLayer], geom: TileGeometry) -> Floorplan {
    let mut placements = Vec::with_capacity(layers.len());
    let mut tile_cursor = 0usize;
    let mut total_imas = 0usize;
    let mut total_xbars = 0usize;
    for l in layers {
        let imas = l.xbars.div_ceil(geom.xbars_per_ima).max(1);
        let tiles = imas.div_ceil(geom.imas_per_tile).max(1);
        placements.push(Placement {
            layer: l.name.clone(),
            xbars: l.xbars,
            imas,
            tiles,
            tile_offset: tile_cursor,
        });
        tile_cursor += tiles;
        total_imas += imas;
        total_xbars += l.xbars;
    }
    Floorplan {
        geometry: geom,
        placements,
        total_tiles: tile_cursor,
        total_imas,
        total_xbars,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::mapper::{map_network, LayerShape};
    use crate::imc::StoxConfig;
    use crate::model::zoo;

    #[test]
    fn placement_covers_all_xbars() {
        let layers = map_network(&zoo::resnet20_cifar(), &StoxConfig::default(), 128);
        let fp = place(&layers, TileGeometry::default());
        assert_eq!(fp.placements.len(), layers.len());
        let sum: usize = layers.iter().map(|l| l.xbars).sum();
        assert_eq!(fp.total_xbars, sum);
        // capacity: every layer fits in its assigned IMAs
        for (p, l) in fp.placements.iter().zip(&layers) {
            assert!(p.imas * fp.geometry.xbars_per_ima >= l.xbars);
        }
    }

    #[test]
    fn tile_offsets_monotone_disjoint() {
        let layers = map_network(&zoo::resnet20_cifar(), &StoxConfig::default(), 128);
        let fp = place(&layers, TileGeometry::default());
        let mut cursor = 0;
        for p in &fp.placements {
            assert_eq!(p.tile_offset, cursor);
            cursor += p.tiles;
        }
        assert_eq!(cursor, fp.total_tiles);
    }

    #[test]
    fn bigger_slicing_needs_more_tiles() {
        let shapes =
            vec![LayerShape::conv("l", 3, 64, 64, 16, true)];
        let cfg1 = StoxConfig { w_slice_bits: 1, ..Default::default() };
        let cfg4 = StoxConfig { w_slice_bits: 4, ..Default::default() };
        let f1 = place(&map_network(&shapes, &cfg1, 128), TileGeometry::default());
        let f4 = place(&map_network(&shapes, &cfg4, 128), TileGeometry::default());
        assert!(f1.total_xbars > f4.total_xbars);
    }
}
