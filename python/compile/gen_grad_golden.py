"""Numpy reference gradients for the §3.3 digit-STE backward.

Generates ``rust/tests/data/grad_golden.json``, the golden that
``rust/tests/grad_equiv.rs`` pins ``train::grad::stox_matmul_backward``
against (tolerance 1e-5).  The conventions here are the *definition* the
Rust side mirrors op-for-op:

* per-slice PS are captured from the exact digit-domain forward (small
  integers summed in f32 — bit-identical on both sides);
* the converter backward is the surrogate derivative ``D`` at those PS:
  ``ideal`` → 1, ``quant``/``sparse`` → ``1[|ps| ≤ 1]`` (clip STE),
  ``sa`` → ``α·1[|α·ps| ≤ 1]`` (hardtanh STE), MTJ family →
  ``α·(1 − tanh²(α·ps))`` (Eq. 1 tanh surrogate);
* the digit STE allocates slope uniformly: ``∂x_i/∂a_q = 2^As − 1`` for
  every stream and ``∂t_j/∂w_q = 2^Ws − 1`` for every slice — the unique
  per-digit split consistent with the recombination identity
  ``Σ_i 2^{i·As}·x_i = (2^Ab − 1)·a_q`` that is uniform across digits;
* activations chain through the clip STE (``1[|a| ≤ 1]``, inclusive).

Inputs of each golden case are *derived from the seed* with the shared
counter RNG (``uniform_in``), identically on both sides, so the file
stores only the expected gradients.

    python -m compile.gen_grad_golden        # from python/
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

from .gen_sweep_golden import (
    Cfg,
    F32,
    mixed_seed,
    quantize_unit,
    signed_digits,
    uniform_in,
)

OUT = pathlib.Path(__file__).resolve().parents[2] / "rust" / "tests" / "data"


# ---------------------------------------------------------------------------
# Surrogate derivatives (rust ``imc::PsSurrogate``)
# ---------------------------------------------------------------------------

DEFAULT_ALPHA = 4.0


def surrogate_grad(spec: str, alpha: float, ps: np.ndarray) -> np.ndarray:
    """``d converted / d ps`` of the named converter's surrogate."""
    name = spec.split(":", 1)[0]
    if name == "ideal":
        return np.ones_like(ps)
    if name in ("quant", "sparse"):
        return np.where(np.abs(ps) <= F32(1.0), F32(1.0), F32(0.0))
    if name == "sa":
        z = F32(DEFAULT_ALPHA) * ps
        return np.where(np.abs(z) <= F32(1.0), F32(DEFAULT_ALPHA), F32(0.0))
    # expected / stox / inhomo: Eq. 1 tanh surrogate
    t = np.tanh(F32(alpha) * ps)
    return F32(alpha) * (F32(1.0) - t * t)


def spec_alpha(spec: str) -> float:
    for kv in spec.partition(":")[2].split(","):
        if kv.startswith("alpha="):
            return float(kv.split("=")[1])
    return DEFAULT_ALPHA


# ---------------------------------------------------------------------------
# Exact digit-domain PS capture + the digit-STE VJP
# ---------------------------------------------------------------------------


def capture_ps(a: np.ndarray, wn: np.ndarray, cfg: Cfg):
    """Per-slice PS ``[B,K,N,I,J]`` plus the padded digit tensors."""
    bsz, m = a.shape
    n = wn.shape[1]
    k_n = cfg.n_arrs(m)
    i_n, j_n = cfg.n_streams, cfg.n_slices
    xd = signed_digits(quantize_unit(a, cfg.a_bits), cfg.a_bits, cfg.a_stream_bits)
    td = signed_digits(quantize_unit(wn, cfg.w_bits), cfg.w_bits, cfg.w_slice_bits)
    m_pad = k_n * cfg.r_arr
    xp = np.zeros((bsz, m_pad, i_n), F32)
    xp[:, :m] = xd
    tp = np.zeros((m_pad, n, j_n), F32)
    tp[:m] = td
    xk = xp.reshape(bsz, k_n, cfg.r_arr, i_n)
    tk = tp.reshape(k_n, cfg.r_arr, n, j_n)
    # digits are small integers: the f32 einsum is exact, so ps matches
    # the Rust integer kernel bit for bit
    ps = np.einsum("bkri,krnj->bknij", xk, tk).astype(F32) * F32(1.0 / cfg.r_arr)
    return ps, xk, tk


def stox_matmul_backward_np(
    a: np.ndarray, wn: np.ndarray, cfg: Cfg, spec: str, g: np.ndarray
):
    """The digit-STE VJP (mirror of ``train::grad::stox_matmul_backward``).

    Returns ``(d_a, d_w)`` — ``d_a`` already masked by the clip STE,
    ``d_w`` with respect to the *normalized* weights.
    """
    bsz, m = a.shape
    n = wn.shape[1]
    k_n = cfg.n_arrs(m)
    i_n, j_n = cfg.n_streams, cfg.n_slices
    ps, xk, tk = capture_ps(a, wn, cfg)
    d = surrogate_grad(spec, spec_alpha(spec), ps)  # [B,K,N,I,J]

    sa = np.asarray([float(1 << (i * cfg.a_stream_bits)) for i in range(i_n)], F32)
    sw = np.asarray([float(1 << (j * cfg.w_slice_bits)) for j in range(j_n)], F32)
    la = float((1 << cfg.a_bits) - 1)
    lw = float((1 << cfg.w_bits) - 1)
    lev = la * lw
    slope_a = float((1 << cfg.a_stream_bits) - 1)
    slope_w = float((1 << cfg.w_slice_bits) - 1)
    denom = F32(lev) * F32(k_n) * F32(cfg.r_arr)
    ca = F32(slope_a) / denom
    cw = F32(slope_w) / denom

    # significance-weighted per-slice gains
    aj = np.einsum("bknij,i,j->bknj", d, sa, sw).astype(F32)
    wi = np.einsum("bknij,i,j->bkni", d, sa, sw).astype(F32)
    d_a = ca * np.einsum("bn,bknj,krnj->bkr", g, aj, tk).astype(F32)
    d_a = d_a.reshape(bsz, k_n * cfg.r_arr)[:, :m]
    d_a = np.where(np.abs(a) <= F32(1.0), d_a, F32(0.0))
    d_w = cw * np.einsum("bn,bkni,bkri->krn", g, wi, xk).astype(F32)
    d_w = d_w.reshape(k_n * cfg.r_arr, n)[:m]
    return d_a.astype(F32), d_w.astype(F32)


def ideal_forward(a: np.ndarray, wn: np.ndarray, cfg: Cfg) -> np.ndarray:
    """Expected forward with the ideal converter (used by the stack
    cases): shift-and-add of the exact per-slice PS — deterministic and
    exactly representable, so both sides agree bitwise."""
    ps, _, _ = capture_ps(a, wn, cfg)
    i_n, j_n = cfg.n_streams, cfg.n_slices
    sa = np.asarray([float(1 << (i * cfg.a_stream_bits)) for i in range(i_n)], F32)
    sw = np.asarray([float(1 << (j * cfg.w_slice_bits)) for j in range(j_n)], F32)
    lev = F32(((1 << cfg.a_bits) - 1) * ((1 << cfg.w_bits) - 1))
    k_n = ps.shape[1]
    norm = F32(1.0) / (lev * F32(k_n) * F32(1.0))
    out = np.zeros(ps.shape[:1] + ps.shape[2:3], F32)  # [B,N]
    # rust fold order: k outer, then j, then i
    for k in range(k_n):
        for j in range(j_n):
            for i in range(i_n):
                out = out + ps[:, k, :, i, j] * (sa[i] * sw[j] * norm)
    return out


def sa_forward(a: np.ndarray, wn: np.ndarray, cfg: Cfg) -> np.ndarray:
    """Expected forward with the 1b-SA converter (sign readout): ±1
    conversions are exactly representable — bitwise-stable stack input."""
    ps, _, _ = capture_ps(a, wn, cfg)
    i_n, j_n = cfg.n_streams, cfg.n_slices
    sa = np.asarray([float(1 << (i * cfg.a_stream_bits)) for i in range(i_n)], F32)
    sw = np.asarray([float(1 << (j * cfg.w_slice_bits)) for j in range(j_n)], F32)
    lev = F32(((1 << cfg.a_bits) - 1) * ((1 << cfg.w_bits) - 1))
    k_n = ps.shape[1]
    norm = F32(1.0) / (lev * F32(k_n) * F32(1.0))
    out = np.zeros(ps.shape[:1] + ps.shape[2:3], F32)
    for k in range(k_n):
        for j in range(j_n):
            for i in range(i_n):
                cv = np.where(ps[:, k, :, i, j] >= 0.0, F32(1.0), F32(-1.0))
                out = out + cv * (sa[i] * sw[j] * norm)
    return out


# ---------------------------------------------------------------------------
# Case inventory
# ---------------------------------------------------------------------------

CFG_A = Cfg(a_bits=4, w_bits=4, a_stream_bits=1, w_slice_bits=4, r_arr=32)
CFG_B = Cfg(a_bits=4, w_bits=4, a_stream_bits=1, w_slice_bits=1, r_arr=16)
CFG_C = Cfg(a_bits=8, w_bits=8, a_stream_bits=2, w_slice_bits=2, r_arr=32)

SINGLE_SPECS = (
    "ideal",
    "quant:bits=4",
    "sparse:bits=4",
    "sa",
    "expected:alpha=4",
    "stox:alpha=4,samples=2",
    "inhomo:alpha=4,base=1,extra=3",
)


def cfg_json(cfg: Cfg) -> dict:
    return {
        "a_bits": cfg.a_bits,
        "w_bits": cfg.w_bits,
        "a_stream_bits": cfg.a_stream_bits,
        "w_slice_bits": cfg.w_slice_bits,
        "r_arr": cfg.r_arr,
    }


def derive_inputs(seed: int, *sizes: int) -> list[np.ndarray]:
    """Consecutive uniform_in(-1, 1) blocks from one counter stream —
    regenerated identically by the Rust test."""
    mx = mixed_seed(seed)
    out = []
    base = 0
    for sz in sizes:
        out.append(
            uniform_in(mx, np.arange(base, base + sz, dtype=np.uint32), -1.0, 1.0)
        )
        base += sz
    return out


def flat(x: np.ndarray) -> list[float]:
    return [float(v) for v in np.asarray(x, F32).ravel()]


def single_case(name: str, spec: str, cfg: Cfg, seed: int, b: int, m: int, n: int):
    a, w, g = derive_inputs(seed, b * m, m * n, b * n)
    a = a.reshape(b, m)
    w = w.reshape(m, n)
    g = g.reshape(b, n)
    d_a, d_w = stox_matmul_backward_np(a, w, cfg, spec, g)
    return {
        "name": name,
        "kind": "single",
        "spec": spec,
        "cfg": cfg_json(cfg),
        "batch": b,
        "m": m,
        "n": n,
        "seed": seed,
        "d_a": flat(d_a),
        "d_w": flat(d_w),
    }


def stack_case(name: str, spec: str, cfg: Cfg, seed: int, b: int, m: int, h: int, n: int):
    """Two chained matmul layers with the clip STE between them; the
    forward converter is deterministic and exactly representable (ideal
    or sa), so the layer-2 input agrees bitwise across languages."""
    a0, w1, w2, g = derive_inputs(seed, b * m, m * h, h * n, b * n)
    a0 = a0.reshape(b, m)
    w1 = w1.reshape(m, h)
    w2 = w2.reshape(h, n)
    g = g.reshape(b, n)
    fwd = ideal_forward if spec == "ideal" else sa_forward
    out1 = fwd(a0, w1, cfg)
    x1 = np.clip(out1, F32(-1.0), F32(1.0))
    d_x1, d_w2 = stox_matmul_backward_np(x1, w2, cfg, spec, g)
    d_x1 = np.where(np.abs(out1) <= F32(1.0), d_x1, F32(0.0))
    d_a0, d_w1 = stox_matmul_backward_np(a0, w1, cfg, spec, d_x1)
    return {
        "name": name,
        "kind": "stack",
        "spec": spec,
        "cfg": cfg_json(cfg),
        "batch": b,
        "m": m,
        "hidden": h,
        "n": n,
        "seed": seed,
        "d_a": flat(d_a0),
        "d_w1": flat(d_w1),
        "d_w2": flat(d_w2),
    }


def build_golden() -> dict:
    cases = []
    for idx, spec in enumerate(SINGLE_SPECS):
        tag = spec.split(":", 1)[0]
        cases.append(
            single_case(f"single_{tag}_A", spec, CFG_A, 101 + idx, 2, 40, 6)
        )
        cases.append(
            single_case(f"single_{tag}_B", spec, CFG_B, 131 + idx, 2, 24, 5)
        )
    # a wider-digit config on the tanh family
    cases.append(single_case("single_stox_C", "stox:alpha=4,samples=2", CFG_C, 171, 2, 48, 4))
    cases.append(
        single_case("single_inhomo_C", "inhomo:alpha=4,base=1,extra=3", CFG_C, 172, 2, 48, 4)
    )
    cases.append(stack_case("stack_ideal_B", "ideal", CFG_B, 201, 2, 24, 8, 5))
    cases.append(stack_case("stack_sa_A", "sa", CFG_A, 202, 2, 40, 8, 5))
    return {"generator": "gen_grad_golden.py", "cases": cases}


def main() -> None:
    golden = build_golden()
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / "grad_golden.json"
    path.write_text(json.dumps(golden, sort_keys=True, separators=(",", ":")))
    n_single = sum(1 for c in golden["cases"] if c["kind"] == "single")
    n_stack = len(golden["cases"]) - n_single
    print(f"wrote {path} ({n_single} single-layer cases, {n_stack} stacks)")


if __name__ == "__main__":
    main()
