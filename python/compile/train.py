"""PS-quantization-aware training (build-time only; never on request path).

Implements the paper's training methodology (§3.2): the exact stochastic
hardware forward (Algorithm 1) with the Eq. 5 collapsed-STE backward, SGD
with momentum and cosine LR, fresh MTJ sampling seeds every step.

Presets regenerate the accuracy experiments:

  * ``table3``      — MNIST-like grid: {1w1a1bs,2w2a2bs,2w2a1bs,4w4a4bs,
                      4w4a1bs} × {1-QF, 4-QF, Mix-QF}, r_arr=128
  * ``table4``      — CIFAR-like: samples {1,4,8,Mix} × {QF, HPF}, 4w4a4bs,
                      r_arr=256 (+ the '1b-SA, HPF' reference row)
  * ``fig7a/b/c/d`` — ablations: first-layer handling, array size,
                      sampling count, slicing, alpha
  * ``sensitivity`` — Fig. 5 Monte-Carlo layer-wise perturbation analysis
  * ``fig4``        — PS distribution collection (StoX vs SA training)
  * ``smoke``       — 1 tiny run (CI)

Every run writes a JSON record (paper row ↔ measured) consumed by
EXPERIMENTS.md and the Rust bench harness; checkpoints feed ``aot.py``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pickle
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model
from .kernels.ref import StoxConfig

ROOT = Path(__file__).resolve().parent.parent  # python/
RESULTS = ROOT / "results"
CHECKPOINTS = ROOT / "checkpoints"


@dataclasses.dataclass(frozen=True)
class TrainHP:
    steps: int = 300
    batch: int = 32
    lr: float = 0.05
    momentum: float = 0.9
    weight_decay: float = 5e-4
    n_train: int = 4096
    n_test: int = 512
    eval_batch: int = 128
    log_every: int = 50
    seed: int = 0


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def make_train_step(spec: model.ModelSpec, hp: TrainHP):
    def loss_fn(params, states, x, y, seed):
        logits, new_states = model.forward(
            params, states, x, spec, train=True, step_seed=seed
        )
        loss = cross_entropy(logits, y)
        return loss, (new_states, logits)

    @jax.jit
    def step(params, states, vel, x, y, seed, lr):
        (loss, (new_states, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, states, x, y, seed)
        acc = (logits.argmax(-1) == y).mean()

        def upd(p, g, v):
            v_new = hp.momentum * v + g + hp.weight_decay * p
            return p - lr * v_new, v_new

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_v = jax.tree_util.tree_leaves(vel)
        new_p, new_v = zip(*[upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)])
        return (
            jax.tree_util.tree_unflatten(tdef, new_p),
            new_states,
            jax.tree_util.tree_unflatten(tdef, new_v),
            loss,
            acc,
        )

    return step


def make_eval(spec: model.ModelSpec):
    @jax.jit
    def eval_batch(params, states, x, y, seed):
        logits, _ = model.forward(
            params, states, x, spec, train=False, step_seed=seed
        )
        return (logits.argmax(-1) == y).sum()

    return eval_batch


def evaluate(params, states, xs, ys, spec, hp: TrainHP, seed: int = 12345) -> float:
    eval_fn = make_eval(spec)
    correct, total = 0, 0
    for i in range(0, len(xs), hp.eval_batch):
        xb = jnp.asarray(xs[i : i + hp.eval_batch])
        yb = jnp.asarray(ys[i : i + hp.eval_batch])
        correct += int(eval_fn(params, states, xb, yb, np.uint32(seed + i)))
        total += len(xb)
    return correct / total


def train_model(spec: model.ModelSpec, hp: TrainHP, dataset: str, verbose=True):
    """Train one variant; returns (record dict, params, states)."""
    (xtr, ytr), (xte, yte) = datasets.get_dataset(
        dataset, hp.n_train, hp.n_test, spec.image_size, seed=hp.seed
    )
    key = jax.random.PRNGKey(hp.seed)
    params, states = model.init_params(spec, key)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    step_fn = make_train_step(spec, hp)

    rs = np.random.RandomState(hp.seed + 1)
    t0 = time.time()
    losses = []
    for it in range(hp.steps):
        idx = rs.randint(0, len(xtr), hp.batch)
        xb, yb = jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx])
        lr = hp.lr * 0.5 * (1 + np.cos(np.pi * it / hp.steps))
        params, states, vel, loss, acc = step_fn(
            params, states, vel, xb, yb, np.uint32(it), lr
        )
        losses.append(float(loss))
        if verbose and (it % hp.log_every == 0 or it == hp.steps - 1):
            print(
                f"  [{spec.name}] step {it:4d} lr {lr:.4f} "
                f"loss {float(loss):.4f} acc {float(acc):.3f}",
                flush=True,
            )
    train_time = time.time() - t0
    test_acc = evaluate(params, states, xte, yte, spec, hp)
    record = {
        "name": spec.name,
        "dataset": dataset,
        "tag": spec.stox.tag,
        "mode": spec.stox.mode,
        "first_layer": spec.first_layer,
        "n_samples": spec.stox.n_samples,
        "layer_samples": spec.layer_samples,
        "r_arr": spec.stox.r_arr,
        "alpha": spec.stox.alpha,
        "steps": hp.steps,
        "test_acc": test_acc,
        "final_loss": float(np.mean(losses[-20:])),
        "loss_curve": losses[:: max(1, hp.steps // 100)],
        "train_time_s": train_time,
        "n_params": model.num_params(params),
    }
    if verbose:
        print(f"  => {spec.name}: test acc {test_acc:.4f} ({train_time:.0f}s)")
    return record, params, states


def save_checkpoint(path: Path, spec, params, states, record):
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        pickle.dump(
            {
                "spec": dataclasses.asdict(spec)
                | {"stox": dataclasses.asdict(spec.stox)},
                "params": jax.tree_util.tree_map(np.asarray, params),
                "states": jax.tree_util.tree_map(np.asarray, states),
                "record": record,
            },
            f,
        )


def load_checkpoint(path: Path):
    with open(path, "rb") as f:
        blob = pickle.load(f)
    sd = dict(blob["spec"])
    sd["stox"] = StoxConfig(**sd["stox"])
    if sd.get("layer_samples"):
        sd["layer_samples"] = tuple(tuple(x) for x in sd["layer_samples"])
    spec = model.ModelSpec(**sd)
    params = jax.tree_util.tree_map(jnp.asarray, blob["params"])
    states = jax.tree_util.tree_map(jnp.asarray, blob["states"])
    return spec, params, states, blob["record"]


# ---------------------------------------------------------------------------
# Monte-Carlo sensitivity (Fig. 5) and Mix derivation
# ---------------------------------------------------------------------------


def sensitivity_analysis(
    spec: model.ModelSpec, params, states, xs, ys, hp: TrainHP,
    sigma: float = 0.15, trials: int = 8,
) -> list[dict]:
    """Per-layer accuracy drop under uniform weight perturbation (Fig. 5).

    For each trainable conv layer, add U(-sigma, sigma)·max|w| noise to that
    layer only and measure the accuracy drop at inference — the paper's
    layer-importance signal used to assign Mix sampling rates.
    """
    base_acc = evaluate(params, states, xs, ys, spec, hp)
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    conv_leaves = [
        (i, jax.tree_util.keystr(kp))
        for i, (kp, leaf) in enumerate(flat)
        if getattr(leaf, "ndim", 0) == 4
    ]
    rs = np.random.RandomState(hp.seed + 99)
    results = []
    for li, (leaf_idx, name) in enumerate(conv_leaves):
        accs = []
        for t in range(trials):
            leaves = [l for _, l in flat]
            w = leaves[leaf_idx]
            scale = float(jnp.max(jnp.abs(w)))
            noise = jnp.asarray(
                rs.uniform(-sigma, sigma, w.shape), jnp.float32
            ) * scale
            leaves[leaf_idx] = w + noise
            p2 = jax.tree_util.tree_unflatten(tdef, leaves)
            accs.append(evaluate(p2, states, xs, ys, spec, hp, seed=7000 + t))
        drop = base_acc - float(np.mean(accs))
        results.append(
            {"layer": li, "leaf": name, "acc_drop": drop, "base_acc": base_acc}
        )
        print(f"  layer {li:2d} {name:28s} drop {drop:+.4f}", flush=True)
    return results


def mix_from_sensitivity(sens: list[dict], n_layers: int) -> tuple:
    """Assign per-layer samples from the sensitivity ranking.

    Top-sensitivity quartile → 4 samples, next quartile → 2, rest → 1
    (the paper: 'only implement 2 or 4 samplings to a few layers').
    Layer indices here are *stochastic-layer* indices (0 = conv-1 slot).
    """
    order = sorted(range(len(sens)), key=lambda i: -sens[i]["acc_drop"])
    q = max(1, len(sens) // 4)
    out = []
    for rank, li in enumerate(order):
        if li == 0:
            continue  # conv-1 handled by first_layer_samples
        if rank < q:
            out.append((li, 4))
        elif rank < 2 * q:
            out.append((li, 2))
    return tuple(out)


# Default Mix assignment (mirrors Fig. 5: early layers most sensitive) used
# when a preset needs Mix without having run the sensitivity pass first.
DEFAULT_MIX = ((1, 4), (2, 4), (3, 2), (4, 2), (5, 2))


# ---------------------------------------------------------------------------
# Presets (one per paper table / figure panel)
# ---------------------------------------------------------------------------


def _spec(dataset: str, **kw) -> model.ModelSpec:
    base = dict(
        num_classes=10,
        in_channels=1 if dataset == "digits" else 3,
        image_size=16,
        base_width=16,
        width_mult=0.5,
        blocks_per_stage=3,
    )
    base.update(kw)
    return model.ModelSpec(**base)


def preset_runs(preset: str, hp: TrainHP) -> list[tuple[str, model.ModelSpec]]:
    """Returns [(dataset, spec)] for a preset."""
    runs = []
    if preset == "smoke":
        spec = _spec(
            "digits", name="smoke",
            stox=StoxConfig(a_bits=2, w_bits=2, w_slice_bits=2, r_arr=128),
            first_layer="qf", blocks_per_stage=1,
        )
        return [("digits", spec)]

    if preset == "table3":
        grids = [
            (1, 1, 1), (2, 2, 2), (2, 2, 1), (4, 4, 4), (4, 4, 1),
        ]
        for (w, a, s) in grids:
            for samp_name, n_samp, mix in (
                ("1-QF", 1, None), ("4-QF", 4, None), ("Mix-QF", 1, DEFAULT_MIX)
            ):
                cfg = StoxConfig(
                    a_bits=a, w_bits=w, w_slice_bits=s, r_arr=128, n_samples=n_samp,
                )
                runs.append(
                    (
                        "digits",
                        _spec(
                            "digits",
                            name=f"t3-{cfg.tag}-{samp_name}",
                            stox=cfg, first_layer="qf", layer_samples=mix,
                        ),
                    )
                )
        return runs

    if preset == "table4":
        base = dict(a_bits=4, w_bits=4, w_slice_bits=4, r_arr=256)
        for fl in ("qf", "hpf"):
            for samp_name, n_samp, mix in (
                ("1", 1, None), ("4", 4, None), ("8", 8, None),
                ("Mix", 1, DEFAULT_MIX),
            ):
                cfg = StoxConfig(**base, n_samples=n_samp)
                runs.append(
                    (
                        "cifar",
                        _spec(
                            "cifar",
                            name=f"t4-{fl}-{samp_name}",
                            stox=cfg, first_layer=fl, layer_samples=mix,
                        ),
                    )
                )
        # deterministic 1b-SA HPF reference ("HPF+1b-SA" row)
        runs.append(
            (
                "cifar",
                _spec(
                    "cifar", name="t4-hpf-1bsa",
                    stox=StoxConfig(**base, mode="sa"), first_layer="hpf",
                ),
            )
        )
        return runs

    if preset == "fig7":
        base = dict(a_bits=4, w_bits=4, w_slice_bits=4)
        mk = lambda name, **kw: runs.append(("cifar", _spec("cifar", name=name, **kw)))
        # (A)+(E): first-layer handling
        mk("f7-1bsa-1bsaqf",
           stox=StoxConfig(**base, r_arr=256, mode="sa"),
           first_layer="qf", first_layer_mode="sa")
        # "1b-SA, QF": 1b-SA everywhere EXCEPT an 8-sample stochastic conv-1
        mk("f7-1bsa-qf",
           stox=StoxConfig(**base, r_arr=256, mode="sa"), first_layer="qf",
           first_layer_mode="stox")
        mk("f7-1bsa-hpf",
           stox=StoxConfig(**base, r_arr=256, mode="sa"), first_layer="hpf")
        mk("f7-stox-qf",
           stox=StoxConfig(**base, r_arr=256), first_layer="qf")
        mk("f7-stox-hpf",
           stox=StoxConfig(**base, r_arr=256), first_layer="hpf")
        # (A): array size sweep
        for r in (64, 128, 256, 512):
            mk(f"f7a-rarr{r}", stox=StoxConfig(**base, r_arr=r), first_layer="hpf")
        # (B): multi-sampling sweep
        for n in (1, 2, 4, 8):
            mk(f"f7b-s{n}",
               stox=StoxConfig(**base, r_arr=256, n_samples=n), first_layer="hpf")
        # (C): sliced vs unsliced
        mk("f7c-sliced",
           stox=StoxConfig(a_bits=4, w_bits=4, w_slice_bits=1, r_arr=256),
           first_layer="hpf")
        mk("f7c-unsliced",
           stox=StoxConfig(a_bits=4, w_bits=4, w_slice_bits=4, r_arr=256),
           first_layer="hpf")
        # (D): alpha sweep
        for alpha in (1.0, 2.0, 4.0, 8.0, 16.0):
            mk(f"f7d-a{alpha:g}",
               stox=StoxConfig(**base, r_arr=256, alpha=alpha), first_layer="hpf")
        return runs

    raise ValueError(f"unknown preset {preset}")


def run_preset(preset: str, hp: TrainHP, out: Path | None):
    runs = preset_runs(preset, hp)
    records = []
    for dataset, spec in runs:
        print(f"== training {spec.name} on {dataset} ==", flush=True)
        record, params, states = train_model(spec, hp, dataset)
        records.append(record)
        ckpt = CHECKPOINTS / f"{spec.name}.pkl"
        save_checkpoint(ckpt, spec, params, states, record)
    out = out or RESULTS / f"{preset}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"preset": preset, "runs": records}, indent=1))
    print(f"wrote {out}")
    return records


def run_sensitivity(hp: TrainHP, out: Path | None, ckpt_name: str = "t4-hpf-1"):
    ckpt = CHECKPOINTS / f"{ckpt_name}.pkl"
    if not ckpt.exists():
        print(f"checkpoint {ckpt} missing; training baseline first")
        spec = _spec(
            "cifar", name=ckpt_name,
            stox=StoxConfig(a_bits=4, w_bits=4, w_slice_bits=4, r_arr=256),
            first_layer="hpf",
        )
        record, params, states = train_model(spec, hp, "cifar")
        save_checkpoint(ckpt, spec, params, states, record)
    spec, params, states, _ = load_checkpoint(ckpt)
    (_, _), (xte, yte) = datasets.get_dataset(
        "cifar", 8, hp.n_test, spec.image_size, seed=hp.seed
    )
    sens = sensitivity_analysis(spec, params, states, xte, yte, hp)
    mix = mix_from_sensitivity(sens, spec.n_stox_layers())
    out = out or RESULTS / "sensitivity.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"sensitivity": sens, "mix": mix}, indent=1))
    print(f"wrote {out}; derived mix = {mix}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--out", type=Path, default=None)
    args = ap.parse_args()

    hp = TrainHP()
    if args.steps is not None:
        hp = dataclasses.replace(hp, steps=args.steps)
    if args.batch is not None:
        hp = dataclasses.replace(hp, batch=args.batch)

    if args.preset == "sensitivity":
        run_sensitivity(hp, args.out)
    else:
        run_preset(args.preset, hp, args.out)


if __name__ == "__main__":
    main()
