"""Generate the committed design-matrix sweep golden for ``rust/tests/sweep.rs``.

The Rust golden test pins ``arch::sweep::run_matrix_sweep`` over a fixed
input — precision tags ``4w4a4bs,8w8a4bs`` × a fixed converter-spec set,
48 golden-workload inputs, seed 2024 — against
``rust/tests/data/sweep_golden.json``.  This script produces that file
from the *python side*: it re-implements the sweep as an exact port of
the Rust pipeline —

  * the counter RNG (``stats/rng.rs``), bit-identical by construction;
  * the golden workload and MVM kernel (``imc/mvm.rs`` ``run_range``)
    with the same float32 operation order, so accuracies match up to
    last-ulp libm ``tanh`` differences (the ``converter_equiv.rs``
    tolerance class);
  * the Fig. 9 cost rollup (``arch/{components,mapper,pipeline,energy}``)
    in pure f64, which matches exactly.

The emitted golden is an envelope ``{"generator": "python-oracle",
"result": …}``; the Rust test compares cost fields exactly and accuracies
to a few input quanta.  Re-blessing from a Rust toolchain
(``UPDATE_SWEEP_GOLDEN=1 cargo test``) switches the envelope to
``generator: "rust"`` and byte-exact comparison.

    python -m compile.gen_sweep_golden        # from python/
"""

from __future__ import annotations

import dataclasses
import json
import math
import pathlib

import numpy as np

F32 = np.float32
OUT = pathlib.Path(__file__).resolve().parents[2] / "rust" / "tests" / "data"

GOLDEN_INPUTS = 48
GOLDEN_SEED = 2024
GOLDEN_TAGS = ("4w4a4bs", "8w8a4bs")

# ---------------------------------------------------------------------------
# Counter RNG (rust/src/stats/rng.rs) — numpy-uint32 arrays, wrapping ops
# ---------------------------------------------------------------------------

_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_GOLDEN_MIX = np.uint32(0x9E3779B9)


def mix32(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * _M1
    x = x ^ (x >> np.uint32(15))
    x = x * _M2
    x = x ^ (x >> np.uint32(16))
    return x


def mixed_seed(seed: int) -> np.uint32:
    """``CounterRng::new(seed).mixed_seed``."""
    return mix32(np.array([np.uint32(seed) ^ _GOLDEN_MIX], np.uint32))[0]


def draw24(mixed: np.uint32, counters: np.ndarray) -> np.ndarray:
    return mix32(counters.astype(np.uint32) ^ mixed) >> np.uint32(8)


def uniform(mixed: np.uint32, counters: np.ndarray) -> np.ndarray:
    return draw24(mixed, counters).astype(F32) * F32(1.0 / (1 << 24))


def uniform_in(mixed: np.uint32, counters: np.ndarray, lo: float, hi: float) -> np.ndarray:
    return F32(lo) + F32(hi - lo) * uniform(mixed, counters)


# ---------------------------------------------------------------------------
# Hardware config + precision tags (rust/src/imc/quant.rs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Cfg:
    a_bits: int = 4
    w_bits: int = 4
    a_stream_bits: int = 1
    w_slice_bits: int = 4
    r_arr: int = 256
    n_samples: int = 1
    alpha: float = 4.0

    @property
    def n_streams(self) -> int:
        return self.a_bits // self.a_stream_bits

    @property
    def n_slices(self) -> int:
        return self.w_bits // self.w_slice_bits

    def n_arrs(self, m: int) -> int:
        return max(1, math.ceil(m / self.r_arr))

    @property
    def tag(self) -> str:
        return f"{self.w_bits}w{self.a_bits}a{self.w_slice_bits}bs"


def cfg_from_tag(tag: str, base: Cfg) -> Cfg:
    w_str, rest = tag.split("w", 1)
    a_str, slice_str = rest.split("a", 1)
    w_bits, a_bits = int(w_str), int(a_str)
    if slice_str:
        assert slice_str.endswith("bs"), tag
        w_slice_bits = int(slice_str[:-2])
    else:
        w_slice_bits = max(1, min(base.w_slice_bits, w_bits))
    return dataclasses.replace(
        base,
        a_bits=a_bits,
        w_bits=w_bits,
        w_slice_bits=w_slice_bits,
        a_stream_bits=max(1, min(base.a_stream_bits, a_bits)),
    )


def quantize_unit(v: np.ndarray, bits: int) -> np.ndarray:
    """f32 `((v+1)*0.5*levels).round_ties_even() as i32` (rust order)."""
    levels = F32((1 << bits) - 1)
    v = np.clip(v.astype(F32), F32(-1.0), F32(1.0))
    return np.round((v + F32(1.0)) * F32(0.5) * levels).astype(np.int32)


def signed_digits(u: np.ndarray, bits: int, digit_bits: int) -> np.ndarray:
    """[..., n_digits] float32 signed digits, LSB first."""
    n_digits = bits // digit_bits
    base = 1 << digit_bits
    shifts = np.arange(n_digits, dtype=np.int32) * digit_bits
    d = (u[..., None] >> shifts) & (base - 1)
    return (2 * d - (base - 1)).astype(F32)


# ---------------------------------------------------------------------------
# Converters (rust/src/imc/convert.rs), slice-at-a-time over column vectors
# ---------------------------------------------------------------------------


def quant_midtread(ps: np.ndarray, bits: int) -> np.ndarray:
    levels = F32((1 << bits) - 1)
    u = np.round((np.clip(ps, F32(-1.0), F32(1.0)) + F32(1.0)) * F32(0.5) * levels)
    return F32(2.0) * u / levels - F32(1.0)


def stochastic_totals(
    alpha: float,
    n_samples: int,
    counter_block: int,
    ps: np.ndarray,
    base0: np.uint32,
    stride: int,
    mixed: np.uint32,
) -> np.ndarray:
    """Unnormalized ±1 sample totals (rust ``stochastic_slice``)."""
    pr = F32(0.5) * (np.tanh(F32(alpha) * ps) + F32(1.0))
    thr = np.ceil(pr.astype(np.float64) * 16777216.0).astype(np.uint32)
    idx = np.arange(len(ps), dtype=np.uint32)
    c0 = (np.uint32(base0) + idx * np.uint32(stride)).astype(np.uint32)
    base = c0 * np.uint32(counter_block)
    total = np.zeros(len(ps), np.int32)
    for s in range(n_samples):
        d = draw24(mixed, base + np.uint32(s))
        total = total + np.where(d < thr, 1, -1).astype(np.int32)
    return total.astype(F32)


class Converter:
    """One registry converter: spec string, label, samples(), cost key."""

    def __init__(self, spec: str, cfg: Cfg):
        self.spec = spec
        self.cfg = cfg
        name, _, rest = spec.partition(":")
        params = {}
        if rest:
            for kv in rest.split(","):
                k, v = kv.split("=")
                params[k] = float(v)
        self.name = name
        self.alpha = params.get("alpha", 4.0)
        self.n_samples = max(1, int(params.get("samples", 1)))
        self.bits = int(params.get("bits", 8 if name == "quant" else 4))
        self.base = max(1, int(params.get("base", 1)))
        self.extra = int(params.get("extra", 3))
        if name == "inhomo":
            self.table = inhomo_table(cfg, self.base, self.extra)

    # -- identity ---------------------------------------------------------
    def label(self) -> str:
        return {
            "ideal": "ideal-ADC",
            "quant": f"quant-ADC({self.bits}b)",
            "sparse": f"sparse-ADC({self.bits}b)",
            "sa": "1b-SA",
            "expected": "expected-MTJ",
            "stox": f"MTJ×{self.n_samples}",
            "inhomo": f"inhomo-MTJ({self.base}..{self.base + self.extra})",
        }[self.name]

    def samples(self) -> int:
        return self.n_samples if self.name == "stox" else 1

    def cost_key(self):
        """(kind, param) mirroring ``PsConvert::cost_key``."""
        if self.name == "ideal":
            return ("adc_fp", 16)
        if self.name == "quant":
            return ("adc_fp", 16) if self.bits >= 8 else ("adc_sparse", 16)
        if self.name == "sparse":
            return ("adc_sparse", 16)
        if self.name == "sa":
            return ("sa", 0)
        if self.name == "expected":
            return ("mtj", 1)
        if self.name == "stox":
            return ("mtj", self.n_samples)
        if self.name == "inhomo":
            # exact fractional mean, charged as millisamples
            # (rust ``PsProcessing::StochasticMtjFrac``)
            mean = sum(float(n) for row in self.table for n in row) / (
                len(self.table) * len(self.table[0])
            )
            return ("mtj_frac", max(1, int(rust_round(mean * 1000.0))))
        raise ValueError(self.name)

    # -- conversion -------------------------------------------------------
    def convert_at(
        self,
        stream: int,
        w_slice: int,
        ps: np.ndarray,
        base0: np.uint32,
        stride: int,
        mixed: np.uint32,
    ) -> np.ndarray:
        if self.name == "ideal":
            return ps.copy()
        if self.name == "quant":
            return quant_midtread(ps, self.bits)
        if self.name == "sparse":
            if np.all(ps == 0.0):
                return np.zeros_like(ps)
            return quant_midtread(ps, self.bits)
        if self.name == "sa":
            return np.where(ps >= 0.0, F32(1.0), F32(-1.0))
        if self.name == "expected":
            return np.tanh(F32(self.alpha) * ps)
        if self.name == "stox":
            return stochastic_totals(
                self.alpha, self.n_samples, self.n_samples, ps, base0, stride, mixed
            )
        if self.name == "inhomo":
            n_ij = self.table[stream][w_slice]
            n_max = self.base + self.extra
            totals = stochastic_totals(
                self.alpha, n_ij, n_max, ps, base0, stride, mixed
            )
            return totals * (F32(1.0) / F32(n_ij))
        raise ValueError(self.name)


def rust_round(x: float) -> float:
    """f64 ``round`` (half away from zero)."""
    return math.floor(x + 0.5) if x >= 0.0 else math.ceil(x - 0.5)


def inhomo_table(cfg: Cfg, base: int, extra: int) -> list[list[int]]:
    i_n, j_n = cfg.n_streams, cfg.n_slices
    da, dw = cfg.a_stream_bits, cfg.w_slice_bits
    sig_max = (i_n - 1) * da + (j_n - 1) * dw
    table = []
    for i in range(i_n):
        row = []
        for j in range(j_n):
            sig = i * da + j * dw
            if sig_max == 0:
                n = base + extra
            else:
                n = base + int(rust_round(extra * sig / sig_max))
            row.append(max(1, n))
        table.append(row)
    return table


# ---------------------------------------------------------------------------
# The MVM kernel, ported from StoxMvm::program / run_range with identical
# f32 operation order (accumulation over rows ascending, per-column adds)
# ---------------------------------------------------------------------------


class Mvm:
    def __init__(self, w: np.ndarray, m: int, n: int, cfg: Cfg):
        self.cfg, self.m, self.n = cfg, m, n
        self.n_arrs = cfg.n_arrs(m)
        uw = quantize_unit(w.reshape(m, n), cfg.w_bits)
        td = signed_digits(uw, cfg.w_bits, cfg.w_slice_bits)  # [m, n, J]
        self.wd = np.zeros((self.n_arrs, cfg.n_slices, cfg.r_arr, n), F32)
        for r in range(m):
            k, rr = divmod(r, cfg.r_arr)
            for j in range(cfg.n_slices):
                self.wd[k, j, rr, :] = td[r, :, j]

    def run(self, a: np.ndarray, batch: int, conv: Converter, seed: int) -> np.ndarray:
        cfg = self.cfg
        i_n, j_n = cfg.n_streams, cfg.n_slices
        samples = F32(conv.samples())
        mixed = mixed_seed(seed)
        sa = [F32(1 << (i * cfg.a_stream_bits)) for i in range(i_n)]
        sw = [F32(1 << (j * cfg.w_slice_bits)) for j in range(j_n)]
        lev = F32(((1 << cfg.a_bits) - 1) * ((1 << cfg.w_bits) - 1))
        norm = F32(1.0) / (lev * F32(self.n_arrs) * samples)
        inv_r = F32(1.0) / F32(cfg.r_arr)
        a = a.reshape(batch, self.m)
        out = np.zeros((batch, self.n), F32)
        for b in range(batch):
            for k in range(self.n_arrs):
                row0 = k * cfg.r_arr
                rows = min(self.m - row0, cfg.r_arr)
                ua = quantize_unit(a[b, row0 : row0 + rows], cfg.a_bits)
                xd = signed_digits(ua, cfg.a_bits, cfg.a_stream_bits)  # [rows, I]
                for j in range(j_n):
                    ps = np.zeros((i_n, self.n), F32)
                    w_sl = self.wd[k, j]
                    for rr in range(rows):
                        # one row feeds every stream; per-element add order
                        # over rr matches the rust kernel exactly
                        ps += xd[rr][:, None] * w_sl[rr][None, :]
                    for i in range(i_n):
                        scale = sa[i] * sw[j] * norm
                        psn = ps[i] * inv_r
                        base0 = np.uint32(
                            (((b * self.n_arrs + k) * self.n) * i_n + i)
                            & 0xFFFFFFFF
                        ) * np.uint32(j_n) + np.uint32(j)
                        cv = conv.convert_at(
                            i, j, psn, base0, i_n * j_n, mixed
                        )
                        out[b] += cv * scale
        return out


# ---------------------------------------------------------------------------
# Golden workload (arch/sweep.rs GoldenWorkload)
# ---------------------------------------------------------------------------

FEATURES, HIDDEN, CLASSES = 96, 32, 10


class GoldenWorkload:
    def __init__(self, cfg: Cfg, n_inputs: int, seed: int):
        self.cfg, self.n, self.seed = cfg, n_inputs, seed
        m, h, c = FEATURES, HIDDEN, CLASSES
        mx = mixed_seed(seed ^ 0x5EEDDA7A)
        w1 = uniform_in(mx, np.arange(m * h, dtype=np.uint32), -1.0, 1.0)
        w2 = uniform_in(
            mx, np.arange(m * h, m * h + h * c, dtype=np.uint32), -1.0, 1.0
        )
        base = m * h + h * c
        inputs = uniform_in(
            mx, np.arange(base, base + n_inputs * m, dtype=np.uint32), -1.0, 1.0
        )
        self.inputs = inputs.reshape(n_inputs, m)
        self.mvm1 = Mvm(w1, m, h, cfg)
        self.mvm2 = Mvm(w2, h, c, cfg)
        ideal = Converter("ideal", cfg)
        o1 = self.mvm1.run(self.inputs, n_inputs, ideal, seed)
        max_abs = F32(np.max(np.abs(o1))) if o1.size else F32(0.0)
        self.gain = F32(1.0) / max_abs if max_abs > 0.0 else F32(1.0)
        h1 = np.clip(o1 * self.gain, F32(-1.0), F32(1.0))
        o2 = self.mvm2.run(h1, n_inputs, ideal, seed ^ 0x9E3779B9)
        self.labels = np.argmax(o2, axis=1)

    def accuracy(self, conv: Converter) -> float:
        o1 = self.mvm1.run(self.inputs, self.n, conv, self.seed)
        h1 = np.clip(o1 * self.gain, F32(-1.0), F32(1.0))
        o2 = self.mvm2.run(h1, self.n, conv, self.seed ^ 0x9E3779B9)
        correct = int(np.sum(np.argmax(o2, axis=1) == self.labels))
        return correct / self.n


# ---------------------------------------------------------------------------
# Cost rollup (arch/{components,mapper,pipeline,energy}.rs), pure f64
# ---------------------------------------------------------------------------

COST = dict(
    dac_energy_pj=2.99e-2,
    dac_area_um2=0.127,
    cell_energy_1b_pj=6.16e-3,
    cell_energy_2b_pj=4.16e-3,
    cell_area_um2=0.0308,
    adc_fp_energy_pj=2.137,
    adc_fp_area_um2=6600.0,
    adc_sparse_energy_pj=1.171,
    adc_sparse_area_um2=2700.0,
    mtj_energy_pj=6.14e-15 * 1e12,
    mtj_area_um2=1.47,
    sa_energy_pj=1.0e-3,
    sa_area_um2=1.2,
    sna_energy_pj=4.1e-3,
    sna_area_um2=28.0,
    adc_latency_ns=1.0,
    mtj_latency_ns=2e-9 * 1e9,
    sa_latency_ns=0.5,
    xbar_read_ns=4.0,
    io_energy_pj=0.18,
    tile_overhead_um2=15_000.0,
    sna_ns=1.0,
)

C_ARR = 128


def ps_energy_pj(key) -> float:
    kind, param = key
    if kind == "adc_fp":
        return COST["adc_fp_energy_pj"]
    if kind == "adc_sparse":
        return COST["adc_sparse_energy_pj"]
    if kind == "sa":
        return COST["sa_energy_pj"]
    if kind == "mtj_frac":
        return COST["mtj_energy_pj"] * (float(param) / 1000.0)
    return COST["mtj_energy_pj"] * float(param)


def ps_area_per_column_um2(key) -> float:
    kind, param = key
    if kind == "adc_fp":
        return COST["adc_fp_area_um2"] / float(param)
    if kind == "adc_sparse":
        return COST["adc_sparse_area_um2"] / float(param)
    if kind == "sa":
        return COST["sa_area_um2"]
    return COST["mtj_area_um2"]


def ps_stage_ns(key, n_cols: int) -> float:
    kind, param = key
    if kind in ("adc_fp", "adc_sparse"):
        return COST["adc_latency_ns"] * float(min(n_cols, param))
    if kind == "sa":
        return COST["sa_latency_ns"]
    if kind == "mtj_frac":
        return COST["mtj_latency_ns"] * (float(param) / 1000.0)
    return COST["mtj_latency_ns"] * float(param)


def key_samples(key) -> int:
    kind, param = key
    if kind == "mtj":
        return param
    if kind == "mtj_frac":
        # whole conversions, mean rounded half-up (rust samples())
        return max(1, (param + 500) // 1000)
    return 1


def resnet20_layers() -> list[dict]:
    layers = [dict(name="conv1", k=3, cin=3, cout=16, h=32)]
    widths, sizes = [16, 32, 64], [32, 16, 8]
    cin = 16
    for s, (w, hw) in enumerate(zip(widths, sizes)):
        for b in range(3):
            layers.append(dict(name=f"s{s}b{b}c1", k=3, cin=cin, cout=w, h=hw))
            layers.append(dict(name=f"s{s}b{b}c2", k=3, cin=w, cout=w, h=hw))
            cin = w
    layers.append(dict(name="fc", k=1, cin=64, cout=10, h=1))
    return layers


def evaluate_design(cfg: Cfg, key, bits_per_cell: int, layers: list[dict]):
    """Port of ``evaluate_design`` for the uniform-spec design points the
    sweep builds (body == first layer, activity 1, no per-layer samples)."""
    cell_e = (
        COST["cell_energy_2b_pj"] if bits_per_cell >= 2 else COST["cell_energy_1b_pj"]
    )
    e_tot = t_tot = a_tot = 0.0
    conv_tot = 0
    xb_tot = 0
    for shape in layers:
        m = shape["k"] * shape["k"] * shape["cin"]
        n = shape["cout"]
        p = shape["h"] * shape["h"]
        n_arrs = cfg.n_arrs(m)
        n_slices = cfg.n_slices
        n_streams = cfg.n_streams
        col_tiles = max(1, math.ceil(2 * n / C_ARR))
        xbars = n_arrs * n_slices * col_tiles
        converter_sites = n * n_arrs * n_slices
        conversions = p * n_streams * n_slices * n_arrs * n
        dac_actions = p * n_streams * m
        cell_actions = p * n_streams * (m * 2 * n_slices)
        sna_actions = conversions
        io_actions = dac_actions + p * n_streams * n

        e_dac = float(dac_actions) * COST["dac_energy_pj"] * 1.0
        e_cell = float(cell_actions) * cell_e * 1.0
        e_ps = float(conversions) * ps_energy_pj(key) * 1.0
        e_sna = float(sna_actions) * COST["sna_energy_pj"] * 1.0
        e_io = float(io_actions) * COST["io_energy_pj"] * 1.0
        energy = e_dac + e_cell + e_ps + e_sna + e_io

        beats = float(p * n_streams) + 2.0
        cols = min(n, 128)
        beat = max(COST["xbar_read_ns"], ps_stage_ns(key, cols), COST["sna_ns"])
        latency = beats * beat

        a_cells = float(xbars) * float(cfg.r_arr * C_ARR) * COST["cell_area_um2"]
        a_dac = float(xbars) * float(cfg.r_arr) * COST["dac_area_um2"]
        a_ps = float(converter_sites) * ps_area_per_column_um2(key)
        a_sna = float(xbars) * COST["sna_area_um2"]
        a_overhead = float(xbars) * COST["tile_overhead_um2"]
        area = a_cells + a_dac + a_ps + a_sna + a_overhead

        e_tot += energy
        t_tot += latency
        a_tot += area
        conv_tot += conversions * key_samples(key)
        xb_tot += xbars
    return e_tot, t_tot, a_tot, e_tot * t_tot, conv_tot, xb_tot


def round_to(x: float, decimals: int) -> float:
    f = 10.0 ** decimals
    return rust_round(x * f) / f


def pareto_front_flags(acc_edp: list[tuple[float, float]]) -> list[bool]:
    order = sorted(
        range(len(acc_edp)), key=lambda i: (acc_edp[i][1], -acc_edp[i][0], i)
    )
    flags = [False] * len(acc_edp)
    best_acc = -math.inf
    for i in order:
        if acc_edp[i][0] > best_acc:
            flags[i] = True
            best_acc = acc_edp[i][0]
    return flags


# ---------------------------------------------------------------------------
# The pinned matrix sweep (mirrors fixed_sweep() in rust/tests/sweep.rs)
# ---------------------------------------------------------------------------

FIXED_SPECS = (
    "ideal",
    "quant:bits=8",
    "sparse:bits=4",
    "sa",
    "expected:alpha=4",
    "stox:alpha=4,samples=1",
    "stox:alpha=4,samples=4",
    "inhomo:alpha=4,base=1,extra=3",
)


def run_fixed_sweep() -> dict:
    base = Cfg()
    tags = [cfg_from_tag(t, base) for t in GOLDEN_TAGS]
    layers = resnet20_layers()
    points = []
    for cfg in tags:
        gw = GoldenWorkload(cfg, GOLDEN_INPUTS, GOLDEN_SEED)
        for spec in FIXED_SPECS:
            conv = Converter(spec, cfg)
            acc = gw.accuracy(conv)
            e, t, a, edp, conversions, xbars = evaluate_design(
                cfg, conv.cost_key(), min(cfg.w_slice_bits, 2), layers
            )
            points.append(
                dict(
                    tag=cfg.tag,
                    spec=spec,
                    label=conv.label(),
                    accuracy=acc,
                    energy_pj=round_to(e, 3),
                    latency_ns=round_to(t, 3),
                    area_um2=round_to(a, 3),
                    edp_pj_ns=round_to(edp, 1),
                    conversions=conversions,
                    xbars=xbars,
                    on_front=False,
                )
            )
    points.sort(
        key=lambda p: (p["edp_pj_ns"], -p["accuracy"], p["tag"], p["spec"])
    )
    flags = pareto_front_flags([(p["accuracy"], p["edp_pj_ns"]) for p in points])
    for p, f in zip(points, flags):
        p["on_front"] = f
    front = [dict(tag=p["tag"], spec=p["spec"]) for p in points if p["on_front"]]
    return dict(
        workload="resnet20_cifar", seed=GOLDEN_SEED, points=points, front=front
    )


def main() -> None:
    result = run_fixed_sweep()
    envelope = dict(generator="python-oracle", result=result)
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / "sweep_golden.json"
    path.write_text(json.dumps(envelope, sort_keys=True, separators=(",", ":")))
    front = result["front"]
    print(
        f"wrote {path} ({len(result['points'])} points, "
        f"{len(front)} on the front: "
        + "  ->  ".join(f"{p['tag']} {p['spec']}" for p in front)
    )


if __name__ == "__main__":
    main()
