"""Numpy mirror of the Rust `train/` subsystem + the trained tiny fixture.

Trains the committed random-init checkpoint ``rust/tests/data/tiny_inhomo``
with the *same* conventions as ``rust/src/train``: hardware-exact
stochastic forward (counter-RNG inhomogeneous MTJ sampling, bit-identical
thresholds and draws), the §3.3 digit-STE tanh-surrogate backward
(``gen_grad_golden.stox_matmul_backward_np`` — the same equations
``rust/tests/grad_equiv.rs`` pins the Rust side against), train-mode
BatchNorm, SGD with momentum/weight-decay, cosine LR, deterministic
counter-RNG batch sampling — and exports the result as
``rust/tests/data/tiny_inhomo_trained`` in the exact manifest format, so
``NativeModel::load_with_config`` reloads it through the
``ConverterRegistry`` with no ``--converter`` override.

The fixture deliberately trains *on the committed 8-image test set*
(memorization, not generalization): its role is to be an
accuracy-bearing checkpoint that strictly beats the random-init fixture
on the committed images, which a few hundred steps of PS-aware training
achieve with wide logit margins.  The evaluation here mirrors
``NativeModel::forward`` (folded BN, im2col path, frozen layer seeds,
exact sampling draws), so the accuracies asserted by
``rust/tests/train.rs`` reproduce on the Rust side.

Deterministic end to end (``python/tests/test_train_fixture.py`` pins
the committed bytes against a fresh run):

    python -m compile.train_fixture        # from python/
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

from . import export_fixture as ef
from .gen_grad_golden import stox_matmul_backward_np, surrogate_grad
from .gen_sweep_golden import (
    Cfg,
    F32,
    draw24,
    inhomo_table,
    mix32,
    mixed_seed,
    quantize_unit,
    signed_digits,
)

OUT = (
    pathlib.Path(__file__).resolve().parents[2]
    / "rust"
    / "tests"
    / "data"
    / "tiny_inhomo_trained"
)

FIXTURE_CFG = Cfg(
    a_bits=4, w_bits=4, a_stream_bits=1, w_slice_bits=4, r_arr=64, alpha=4.0
)
BODY_SPEC = "inhomo:alpha=4,base=1,extra=3"

# training hyperparameters of the committed fixture (recorded in its
# checkpoint_record and in EXPERIMENTS.md §Training)
HP = dict(steps=400, batch=4, lr=0.05, momentum=0.9, weight_decay=5e-4, seed=0)


def layer_seed(step_seed: int, layer_idx: int) -> np.uint32:
    x = np.uint32(step_seed & 0xFFFFFFFF) ^ np.uint32((0xA511E9B3 + layer_idx) & 0xFFFFFFFF)
    return mix32(np.array([x], np.uint32))[0]


# ---------------------------------------------------------------------------
# im2col (rust imc::im2col mirror) and its adjoint
# ---------------------------------------------------------------------------


def im2col_np(x: np.ndarray, kh: int, kw: int, stride: int):
    b, h, w, c = x.shape
    pad = (kh - 1) // 2
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    xp = np.zeros((b, h + 2 * pad, w + 2 * pad, c), F32)
    xp[:, pad : pad + h, pad : pad + w, :] = x
    patches = np.zeros((b, ho, wo, kh * kw * c), F32)
    for ky in range(kh):
        for kx in range(kw):
            sub = xp[:, ky : ky + ho * stride : stride, kx : kx + wo * stride : stride, :]
            patches[:, :, :, (ky * kw + kx) * c : (ky * kw + kx + 1) * c] = sub
    return patches.reshape(b * ho * wo, kh * kw * c), ho, wo


def col2im_np(dp: np.ndarray, b: int, h: int, w: int, c: int, kh: int, kw: int, stride: int):
    pad = (kh - 1) // 2
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    dp = dp.reshape(b, ho, wo, kh * kw * c)
    dxp = np.zeros((b, h + 2 * pad, w + 2 * pad, c), F32)
    for ky in range(kh):
        for kx in range(kw):
            dxp[:, ky : ky + ho * stride : stride, kx : kx + wo * stride : stride, :] += dp[
                :, :, :, (ky * kw + kx) * c : (ky * kw + kx + 1) * c
            ]
    return dxp[:, pad : pad + h, pad : pad + w, :]


# ---------------------------------------------------------------------------
# Crossbar MVM with capture (rust StoxMvm::run_capture mirror)
# ---------------------------------------------------------------------------


class InhomoConv:
    """§3.2.3 inhomogeneous MTJ converter, counter-exact with Rust."""

    def __init__(self, alpha: float, base: int, extra: int, cfg: Cfg):
        self.alpha = alpha
        self.base = max(1, base)
        self.extra = extra
        self.table = inhomo_table(cfg, self.base, extra)
        self.n_max = self.base + extra

    def samples(self) -> int:
        return 1

    def convert(self, i, j, psn, counters, mixed):
        n_ij = self.table[i][j]
        pr = F32(0.5) * (np.tanh(F32(self.alpha) * psn) + F32(1.0))
        thr = np.ceil(pr.astype(np.float64) * 16777216.0).astype(np.uint32)
        base = counters * np.uint32(self.n_max)
        total = np.zeros(psn.shape, np.int32)
        for s in range(n_ij):
            d = draw24(mixed, base + np.uint32(s))
            total = total + np.where(d < thr, 1, -1).astype(np.int32)
        return total.astype(F32) * (F32(1.0) / F32(n_ij))


def mvm_capture(a2d: np.ndarray, wn2d: np.ndarray, cfg: Cfg, conv, seed):
    """(out [P,N], ps [P,K,N,I,J]) — fold order mirrors the Rust kernel."""
    p_n, m = a2d.shape
    n = wn2d.shape[1]
    k_n = cfg.n_arrs(m)
    i_n, j_n = cfg.n_streams, cfg.n_slices
    xd = signed_digits(quantize_unit(a2d, cfg.a_bits), cfg.a_bits, cfg.a_stream_bits)
    td = signed_digits(quantize_unit(wn2d, cfg.w_bits), cfg.w_bits, cfg.w_slice_bits)
    m_pad = k_n * cfg.r_arr
    xp = np.zeros((p_n, m_pad, i_n), F32)
    xp[:, :m] = xd
    tp = np.zeros((m_pad, n, j_n), F32)
    tp[:m] = td
    xk = xp.reshape(p_n, k_n, cfg.r_arr, i_n)
    tk = tp.reshape(k_n, cfg.r_arr, n, j_n)
    ps = np.einsum("pkri,krnj->pknij", xk, tk).astype(F32) * F32(1.0 / cfg.r_arr)

    sa = [F32(1 << (i * cfg.a_stream_bits)) for i in range(i_n)]
    sw = [F32(1 << (j * cfg.w_slice_bits)) for j in range(j_n)]
    lev = F32(((1 << cfg.a_bits) - 1) * ((1 << cfg.w_bits) - 1))
    norm = F32(1.0) / (lev * F32(k_n) * F32(conv.samples()))
    mixed = mixed_seed(int(seed))
    out = np.zeros((p_n, n), F32)
    pcol = np.arange(p_n, dtype=np.uint32)[:, None]
    ccol = np.arange(n, dtype=np.uint32)[None, :]
    for k in range(k_n):
        for j in range(j_n):
            for i in range(i_n):
                counters = (
                    ((pcol * np.uint32(k_n) + np.uint32(k)) * np.uint32(n) + ccol)
                    * np.uint32(i_n)
                    + np.uint32(i)
                ) * np.uint32(j_n) + np.uint32(j)
                cv = conv.convert(i, j, ps[:, k, :, i, j], counters, mixed)
                out = out + cv * (sa[i] * sw[j] * norm)
    return out, ps


# ---------------------------------------------------------------------------
# Parameter containers
# ---------------------------------------------------------------------------


def load_fixture_params():
    """Random-init tensors of the committed fixture, as a name → array map."""
    tensors = ef.build_tensors()
    return {name: arr.copy() for name, arr in tensors}


def conv_names():
    """(weight key, bn prefix, stride, layer_idx, cin, cout) per conv."""
    w1, w2, w3 = ef.widths()
    out = [("['params']['conv1']", "['bn1']", 1, 0, ef.SPEC["in_channels"], w1)]
    cin = w1
    li = 1
    for s, cout in enumerate((w1, w2, w3)):
        for b in range(ef.SPEC["blocks_per_stage"]):
            stride = 2 if (s > 0 and b == 0) else 1
            p = f"['params']['stages'][{s}][{b}]"
            out.append((f"{p}['conv1']", f"['stages'][{s}][{b}]['bn1']", stride, li, cin, cout))
            li += 1
            out.append((f"{p}['conv2']", f"['stages'][{s}][{b}]['bn2']", 1, li, cout, cout))
            li += 1
            cin = cout
    return out


# ---------------------------------------------------------------------------
# Inference forward (NativeModel::forward mirror, folded BN)
# ---------------------------------------------------------------------------


def normalize_weights(w: np.ndarray) -> np.ndarray:
    scale = F32(np.max(np.abs(w.astype(F32)))) + F32(1e-8)
    return (w.astype(F32) / scale).astype(F32)


def bn_fold(params, prefix):
    gamma = params[f"['params']{prefix}['gamma']"].astype(F32)
    beta = params[f"['params']{prefix}['beta']"].astype(F32)
    mean = params[f"['states']{prefix}['mean']"].astype(F32)
    var = params[f"['states']{prefix}['var']"].astype(F32)
    scale = gamma / np.sqrt(var + F32(1e-5))
    shift = beta - mean * scale
    return scale, shift


def eval_forward(params, x: np.ndarray, step_seed: int, cfg: Cfg = FIXTURE_CFG):
    """Logits of a batch (NHWC in [-1,1]) under the inhomo converter."""
    conv = InhomoConv(cfg.alpha, 1, 3, cfg)
    b = x.shape[0]
    h = w = ef.SPEC["image_size"]
    specs = conv_names()

    def stox_conv(xin, key, stride, li, cin, cout):
        wt = params[key]
        wn = normalize_weights(wt).reshape(-1, cout)
        xc = np.clip(xin, F32(-1.0), F32(1.0))
        patches, ho, wo = im2col_np(xc, 3, 3, stride)
        out, _ = mvm_capture(patches, wn, cfg, conv, layer_seed(step_seed, li))
        return out.reshape(b, ho, wo, cout), ho, wo

    key, bnp, stride, li, cin, cout = specs[0]
    hcur, hh, ww_ = stox_conv(x, key, stride, li, cin, cout)
    scale, shift = bn_fold(params, bnp)
    hcur = hcur * scale + shift
    c = cout
    idx = 1
    w1, w2, w3 = ef.widths()
    for s, cout_s in enumerate((w1, w2, w3)):
        for blk in range(ef.SPEC["blocks_per_stage"]):
            key1, bn1, stride, li1, cin1, cout1 = specs[idx]
            key2, bn2, _, li2, _, _ = specs[idx + 1]
            idx += 2
            # shortcut: strided subsample + zero channel pad
            sc = hcur[:, ::stride, ::stride, :]
            if c < cout1:
                sc = np.pad(sc, ((0, 0), (0, 0), (0, 0), (0, cout1 - c)))
            o1, h1, w1_ = stox_conv(hcur, key1, stride, li1, cin1, cout1)
            s1, sh1 = bn_fold(params, bn1)
            o1 = o1 * s1 + sh1
            o2, h2, w2_ = stox_conv(o1, key2, 1, li2, cout1, cout1)
            s2, sh2 = bn_fold(params, bn2)
            o2 = o2 * s2 + sh2
            hcur = o2 + sc.astype(F32)
            hh, ww_, c = h2, w2_, cout1
    pooled = hcur.reshape(b, hh * ww_, c).mean(axis=1).astype(F32)
    fc_w = params["['params']['fc_w']"].astype(F32)
    fc_b = params["['params']['fc_b']"].astype(F32)
    return (pooled @ fc_w + fc_b).astype(F32)


def eval_accuracy(params, images, labels, batch=8, seed=0):
    """Mirror of `NativeModel::accuracy` (same batching, same seeds)."""
    n = len(labels)
    correct = 0
    i = 0
    while i < n:
        bsz = min(batch, n - i)
        logits = eval_forward(params, images[i : i + bsz], seed + i)
        correct += int(np.sum(np.argmax(logits, axis=1) == labels[i : i + bsz]))
        i += bsz
    return correct / n


def logit_margins(params, images, labels, seed=0):
    """Per-image (top logit − best wrong logit); positive = correct with
    that margin.  Used to confirm the fixture's accuracy is robust to
    last-ulp cross-language differences."""
    logits = eval_forward(params, images, seed)
    margins = []
    for row, lab in zip(logits, labels):
        wrong = np.delete(row, lab)
        margins.append(float(row[lab] - np.max(wrong)))
    return margins


# ---------------------------------------------------------------------------
# Training (rust train::Trainer mirror)
# ---------------------------------------------------------------------------


def bn_forward_train(x2d, gamma, beta, state_mean, state_var, momentum=0.9):
    """x2d: [N_elems, C] view; returns (y, tape); updates running stats."""
    mean = x2d.astype(np.float64).mean(axis=0)
    var = x2d.astype(np.float64).var(axis=0)
    inv_std = (1.0 / np.sqrt(var.astype(F32) + F32(1e-5))).astype(F32)
    xhat = ((x2d - mean.astype(F32)) * inv_std).astype(F32)
    y = (xhat * gamma + beta).astype(F32)
    state_mean[:] = momentum * state_mean + (1.0 - momentum) * mean.astype(F32)
    state_var[:] = momentum * state_var + (1.0 - momentum) * var.astype(F32)
    return y, (xhat, inv_std, x2d.shape[0])


def bn_backward(tape, gamma, gy2d):
    xhat, inv_std, count = tape
    dbeta = gy2d.sum(axis=0).astype(F32)
    dgamma = (gy2d * xhat).sum(axis=0).astype(F32)
    gx = (gamma * inv_std / F32(count)) * (
        F32(count) * gy2d - dbeta - xhat * dgamma
    )
    return gx.astype(F32), dgamma, dbeta


def softmax_ce(logits, labels):
    mx = logits.max(axis=1, keepdims=True)
    e = np.exp(logits - mx)
    p = e / e.sum(axis=1, keepdims=True)
    n = len(labels)
    loss = float(np.mean(-np.log(p[np.arange(n), labels] + 1e-30)))
    d = p.copy()
    d[np.arange(n), labels] -= 1.0
    return loss, (d / n).astype(F32)


def sgd(p, v, g, lr, momentum, wd):
    v[:] = momentum * v + g + wd * p
    p[:] = p - lr * v


def batch_indices(seed, it, batch, n):
    mx = mixed_seed(seed ^ 0x0DA7A5E1)
    c = np.arange(it * batch, (it + 1) * batch, dtype=np.uint32)
    return (draw24(mx, c).astype(np.int64) % n).tolist()


def train(params, images, labels, hp=HP, cfg: Cfg = FIXTURE_CFG, verbose=True):
    """SGD over the committed test-set images; mutates `params` in place.
    Returns the per-step loss list."""
    conv = InhomoConv(cfg.alpha, 1, 3, cfg)
    specs = conv_names()
    vel = {k: np.zeros_like(v) for k, v in params.items() if k.startswith("['params']")}
    n = len(labels)
    losses = []

    for it in range(hp["steps"]):
        idx = batch_indices(hp["seed"], it, hp["batch"], n)
        xb = images[idx].astype(F32)
        yb = labels[idx]
        b = len(idx)
        step_seed = (hp["seed"] + it) & 0xFFFFFFFF
        lr = F32(hp["lr"] * 0.5 * (1.0 + np.cos(np.pi * it / hp["steps"])))

        # ---------- forward with tape ----------
        tapes = []

        def conv_fwd(xin, key, stride, li, cin, cout):
            wt = params[key].astype(F32)
            scale = F32(np.max(np.abs(wt))) + F32(1e-8)
            wn = (wt / scale).astype(F32).reshape(-1, cout)
            patches, ho, wo = im2col_np(xin, 3, 3, stride)
            out, ps = mvm_capture(patches, wn, cfg, conv, layer_seed(step_seed, li))
            tape = dict(
                key=key, x=xin, patches=patches, ps=ps, wn=wn, scale=scale,
                stride=stride, cin=cin, cout=cout, ho=ho, wo=wo,
            )
            return out.reshape(b, ho, wo, cout), tape

        def bn_fwd(y4d, prefix, cout):
            gamma = params[f"['params']{prefix}['gamma']"]
            beta = params[f"['params']{prefix}['beta']"]
            y2d = y4d.reshape(-1, cout)
            out, tape = bn_forward_train(
                y2d, gamma, beta,
                params[f"['states']{prefix}['mean']"],
                params[f"['states']{prefix}['var']"],
            )
            return out.reshape(y4d.shape), (prefix, tape, cout)

        key, bnp, stride, li, cin, cout = specs[0]
        h0, t_c1 = conv_fwd(xb, key, stride, li, cin, cout)
        h, t_b1 = bn_fwd(h0, bnp, cout)
        c = cout
        idx_l = 1
        w1, w2, w3 = ef.widths()
        for s, _cout_s in enumerate((w1, w2, w3)):
            for blk in range(ef.SPEC["blocks_per_stage"]):
                key1, bn1p, stride, li1, cin1, cout1 = specs[idx_l]
                key2, bn2p, _, li2, _, _ = specs[idx_l + 1]
                idx_l += 2
                sc = h[:, ::stride, ::stride, :]
                if c < cout1:
                    sc = np.pad(sc, ((0, 0), (0, 0), (0, 0), (0, cout1 - c)))
                o1, tc1 = conv_fwd(h, key1, stride, li1, cin1, cout1)
                o1b, tb1 = bn_fwd(o1, bn1p, cout1)
                o2, tc2 = conv_fwd(o1b, key2, 1, li2, cout1, cout1)
                o2b, tb2 = bn_fwd(o2, bn2p, cout1)
                out = (o2b + sc).astype(F32)
                tapes.append(dict(tc1=tc1, tb1=tb1, tc2=tc2, tb2=tb2,
                                  in_c=c, stride=stride, cout=cout1))
                h = out
                c = cout1
        hh, ww_ = h.shape[1], h.shape[2]
        pooled = h.reshape(b, hh * ww_, c).mean(axis=1).astype(F32)
        fc_w = params["['params']['fc_w']"].astype(F32)
        fc_b = params["['params']['fc_b']"].astype(F32)
        logits = (pooled @ fc_w + fc_b).astype(F32)
        loss, dlogits = softmax_ce(logits, yb)
        losses.append(loss)

        # ---------- backward ----------
        d_pooled = (dlogits @ fc_w.T).astype(F32)
        d_fc_w = (pooled.T @ dlogits).astype(F32)
        d_fc_b = dlogits.sum(axis=0).astype(F32)
        gh = np.repeat(d_pooled[:, None, :] / F32(hh * ww_), hh * ww_, axis=1)
        gh = gh.reshape(b, hh, ww_, c)
        sgd(params["['params']['fc_w']"], vel["['params']['fc_w']"], d_fc_w,
            lr, hp["momentum"], hp["weight_decay"])
        sgd(params["['params']['fc_b']"], vel["['params']['fc_b']"], d_fc_b,
            lr, hp["momentum"], hp["weight_decay"])

        def conv_bwd(tape, g4d):
            cout = tape["cout"]
            g2d = g4d.reshape(-1, cout)
            spec_str = BODY_SPEC
            fake_cfg = cfg
            d_patches, d_wn = backward_mvm(
                tape["patches"], tape["wn"], fake_cfg, spec_str, tape["ps"], g2d
            )
            dx = col2im_np(
                d_patches, b, tape["x"].shape[1], tape["x"].shape[2],
                tape["cin"], 3, 3, tape["stride"],
            )
            dx = np.where(np.abs(tape["x"]) <= F32(1.0), dx, F32(0.0)).astype(F32)
            dw = (d_wn / tape["scale"]).astype(F32)
            return dx, dw.reshape(params[tape["key"]].shape)

        def bn_bwd(tb, g4d):
            prefix, tape, cout = tb
            gamma = params[f"['params']{prefix}['gamma']"]
            gx, dgamma, dbeta = bn_backward(tape, gamma, g4d.reshape(-1, cout))
            sgd(params[f"['params']{prefix}['gamma']"],
                vel[f"['params']{prefix}['gamma']"], dgamma,
                lr, hp["momentum"], hp["weight_decay"])
            sgd(params[f"['params']{prefix}['beta']"],
                vel[f"['params']{prefix}['beta']"], dbeta,
                lr, hp["momentum"], hp["weight_decay"])
            return gx.reshape(g4d.shape)

        for tb in reversed(tapes):
            stride, in_c, cout1 = tb["stride"], tb["in_c"], tb["cout"]
            # shortcut adjoint
            g_sc = gh[:, :, :, :in_c] if in_c < cout1 else gh
            hin, win = tb["tc1"]["x"].shape[1], tb["tc1"]["x"].shape[2]
            g_short = np.zeros((b, hin, win, in_c), F32)
            g_short[:, ::stride, ::stride, :] = g_sc
            g_o2 = bn_bwd(tb["tb2"], gh)
            g_mid, dw2 = conv_bwd(tb["tc2"], g_o2)
            sgd(params[tb["tc2"]["key"]], vel[tb["tc2"]["key"]], dw2,
                lr, hp["momentum"], hp["weight_decay"])
            g_o1 = bn_bwd(tb["tb1"], g_mid)
            g_in, dw1 = conv_bwd(tb["tc1"], g_o1)
            sgd(params[tb["tc1"]["key"]], vel[tb["tc1"]["key"]], dw1,
                lr, hp["momentum"], hp["weight_decay"])
            gh = (g_in + g_short).astype(F32)

        g_h0 = bn_bwd(t_b1, gh)
        _, dw0 = conv_bwd(t_c1, g_h0)
        sgd(params[t_c1["key"]], vel[t_c1["key"]], dw0,
            lr, hp["momentum"], hp["weight_decay"])

        if verbose and (it % 50 == 0 or it == hp["steps"] - 1):
            bacc = float(np.mean(np.argmax(logits, axis=1) == yb))
            print(f"  step {it:4d} lr {float(lr):.4f} loss {loss:.4f} acc {bacc:.2f}",
                  flush=True)
    return losses


def backward_mvm(patches, wn, cfg, spec_str, ps, g2d):
    """Digit-STE VJP reusing the golden-generator equations, but fed the
    *captured* PS of the stochastic forward (same convention as Rust)."""
    p_n, m = patches.shape
    n = wn.shape[1]
    k_n = cfg.n_arrs(m)
    i_n, j_n = cfg.n_streams, cfg.n_slices
    d = surrogate_grad(spec_str, 4.0, ps)  # [P,K,N,I,J]
    xd = signed_digits(quantize_unit(patches, cfg.a_bits), cfg.a_bits, cfg.a_stream_bits)
    td = signed_digits(quantize_unit(wn, cfg.w_bits), cfg.w_bits, cfg.w_slice_bits)
    m_pad = k_n * cfg.r_arr
    xp = np.zeros((p_n, m_pad, i_n), F32)
    xp[:, :m] = xd
    tp = np.zeros((m_pad, n, j_n), F32)
    tp[:m] = td
    xk = xp.reshape(p_n, k_n, cfg.r_arr, i_n)
    tk = tp.reshape(k_n, cfg.r_arr, n, j_n)
    sa = np.asarray([float(1 << (i * cfg.a_stream_bits)) for i in range(i_n)], F32)
    sw = np.asarray([float(1 << (j * cfg.w_slice_bits)) for j in range(j_n)], F32)
    lev = float(((1 << cfg.a_bits) - 1) * ((1 << cfg.w_bits) - 1))
    denom = F32(lev) * F32(k_n) * F32(cfg.r_arr)
    ca = F32((1 << cfg.a_stream_bits) - 1) / denom
    cw = F32((1 << cfg.w_slice_bits) - 1) / denom
    aj = np.einsum("pknij,i,j->pknj", d, sa, sw).astype(F32)
    wi = np.einsum("pknij,i,j->pkni", d, sa, sw).astype(F32)
    d_p = ca * np.einsum("pn,pknj,krnj->pkr", g2d, aj, tk).astype(F32)
    d_p = d_p.reshape(p_n, m_pad)[:, :m]
    d_w = cw * np.einsum("pn,pkni,pkri->krn", g2d, wi, xk).astype(F32)
    d_w = d_w.reshape(m_pad, n)[:m]
    return d_p.astype(F32), d_w.astype(F32)


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------


def export_trained(params, losses, outdir: pathlib.Path) -> dict:
    outdir.mkdir(parents=True, exist_ok=True)
    # tensor order = export_fixture order (the loader matches by name)
    order = [name for name, _ in ef.build_tensors()]
    entries, blobs, offset = [], [], 0
    for name in order:
        arr = np.ascontiguousarray(params[name], dtype=np.float32)
        entries.append(
            {"name": name, "shape": list(arr.shape), "offset": offset, "numel": int(arr.size)}
        )
        blobs.append(arr.tobytes())
        offset += int(arr.size)
    (outdir / "weights.bin").write_bytes(b"".join(blobs))

    images, labels = ef.build_testset()
    (outdir / "testset.bin").write_bytes(images.tobytes() + labels.tobytes())

    spec = dict(ef.SPEC)
    spec["name"] = "tiny-inhomo-trained"
    spec["stox"] = dict(ef.SPEC["stox"])
    spec["stox"]["mode"] = "inhomo:base=1,extra=3"
    curve = [float(l) for l in losses[:: max(1, len(losses) // 100)]]
    manifest = {
        "spec": spec,
        "checkpoint_record": {
            "note": (
                "PS-quantization-aware trained fixture (train_fixture.py, the "
                "numpy mirror of rust/src/train; trained on the committed "
                "8-image testset by design)"
            ),
            "seed": HP["seed"],
            "steps": HP["steps"],
            "final_loss": float(np.mean(losses[-5:])),
            "trained_with": BODY_SPEC,
            "loss_curve": curve,
        },
        "layers": ef.conv_layer_shapes(),
        "models": [],
        "mvms": [],
        "weights": {"file": "weights.bin", "tensors": entries, "total_f32": offset},
        "testset": {
            "file": "testset.bin",
            "dataset": "synth",
            "n": ef.TESTSET_N,
            "image_shape": [
                ef.SPEC["image_size"],
                ef.SPEC["image_size"],
                ef.SPEC["in_channels"],
            ],
        },
    }
    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def run(verbose=True):
    """Train + evaluate + export; returns (params, losses, accuracies)."""
    params = load_fixture_params()
    random_params = load_fixture_params()
    images, labels = ef.build_testset()
    images = images.astype(F32)
    losses = train(params, images, labels, verbose=verbose)
    accs = {}
    for seed in (0, 7, 777):
        accs[seed] = (
            eval_accuracy(random_params, images, labels, seed=seed),
            eval_accuracy(params, images, labels, seed=seed),
        )
    return params, losses, accs


def main() -> None:
    params, losses, accs = run()
    for seed, (ra, ta) in accs.items():
        print(f"seed {seed}: random-init {ra:.3f} -> trained {ta:.3f}")
    margins = logit_margins(params, ef.build_testset()[0].astype(F32),
                            ef.build_testset()[1], seed=0)
    print("trained logit margins:", [f"{m:+.3f}" for m in margins])
    assert all(ta > ra for ra, ta in accs.values()), "trained must beat random-init"
    export_trained(params, losses, OUT)
    print(f"wrote trained fixture to {OUT} (loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f})")


if __name__ == "__main__":
    main()
