"""Generate the cross-language golden vectors for ``rust/tests/parity.rs``.

Runs the pure-jnp oracle (``kernels/ref.py``) over a fixed matrix of
configurations and dumps inputs + outputs to
``rust/tests/data/mvm_golden.json``.  The Rust functional crossbar must
reproduce these outputs to 1e-5 (bit-exact stochastic sampling; f32
accumulation-order differences only).

    python -m compile.gen_golden          # from python/

Regenerate only when the oracle semantics change (the counter layout and
threshold rule are frozen contracts).
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

from .kernels import ref

OUT = pathlib.Path(__file__).resolve().parents[2] / "rust" / "tests" / "data"

# (b, m, n, a_bits, w_bits, w_slice_bits, r_arr, n_samples, alpha, mode, seed
#  [, params]) — params carries the mode-specific spec knobs (sparse bits,
# inhomo base/extra) and is emitted verbatim into the JSON record so the
# Rust side can rebuild the exact `PsConverterSpec`.
CASES = [
    (2, 96, 7, 4, 4, 4, 64, 2, 4.0, "stox", 5),      # case 0 MUST be stox
    (2, 64, 5, 4, 4, 1, 32, 1, 4.0, "stox", 9),      # sliced weights
    (1, 300, 8, 4, 4, 4, 256, 1, 4.0, "stox", 42),   # multi-subarray + pad
    (2, 80, 6, 4, 4, 4, 64, 1, 4.0, "sa", 7),
    (2, 80, 6, 4, 4, 4, 64, 1, 2.0, "expected", 7),
    (2, 80, 6, 8, 8, 2, 64, 1, 4.0, "ideal", 7),
    (1, 50, 4, 2, 2, 1, 64, 3, 4.0, "stox", 11),     # low precision, multi-sample
    # registry-only converters (PR-1 additions) — pinned against the oracle
    (2, 96, 7, 4, 4, 4, 64, 1, 4.0, "sparse", 13, {"bits": 4}),
    (1, 300, 8, 4, 4, 4, 256, 1, 4.0, "sparse", 21, {"bits": 2}),
    (2, 64, 5, 4, 4, 1, 32, 1, 4.0, "inhomo", 23, {"base": 1, "extra": 3}),
    (1, 50, 4, 4, 4, 4, 64, 1, 4.0, "inhomo", 29, {"base": 2, "extra": 2}),
]


def rand_unit(rs: np.random.RandomState, n: int) -> np.ndarray:
    return (rs.rand(n).astype(np.float32) * 2.0 - 1.0).astype(np.float32)


def main() -> None:
    out = []
    for case in CASES:
        b, m, n, ab, wb, ws, r_arr, ns, alpha, mode, seed = case[:11]
        params: dict = case[11] if len(case) > 11 else {}
        cfg = ref.StoxConfig(
            a_bits=ab,
            w_bits=wb,
            a_stream_bits=1,
            w_slice_bits=ws,
            r_arr=r_arr,
            n_samples=ns,
            alpha=alpha,
            mode=mode,
            sparse_bits=params.get("bits", 4),
            base_samples=params.get("base", 1),
            extra_samples=params.get("extra", 3),
        )
        rs = np.random.RandomState(1000 + seed)
        a = rand_unit(rs, b * m).reshape(b, m)
        w = rand_unit(rs, m * n).reshape(m, n)
        o = np.asarray(ref.stox_mvm(a, w, cfg, seed=seed), dtype=np.float32)
        record = {
            "b": b,
            "m": m,
            "n": n,
            "a_bits": ab,
            "w_bits": wb,
            "w_slice_bits": ws,
            "r_arr": r_arr,
            "n_samples": ns,
            "alpha": alpha,
            "mode": mode,
            "seed": seed,
            "a": [float(v) for v in a.reshape(-1)],
            "w": [float(v) for v in w.reshape(-1)],
            "out": [float(v) for v in o.reshape(-1)],
        }
        record.update(params)
        out.append(record)
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / "mvm_golden.json"
    path.write_text(json.dumps(out))
    print(f"wrote {len(out)} cases to {path} ({path.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
