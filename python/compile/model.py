"""L2 model zoo: ResNet-20 (and a small CNN) built from StoX layers.

The network structure follows the paper's evaluation: ResNet-20 (3 stages ×
3 basic blocks × 2 convs + first conv + FC) where every convolution is a
crossbar-mapped ``stox_conv2d``.  Variants (§4.1 naming):

  * ``first_layer='hpf'`` — full-precision conv-1 (the state-of-the-art QAT
    convention the paper challenges);
  * ``first_layer='qf'``  — conv-1 is also stochastic, with
    ``first_layer_samples`` MTJ reads (8 in the paper);
  * ``layer_samples``     — per-layer sampling override implementing the
    Monte-Carlo-guided inhomogeneous "Mix" scheme;
  * ``mode='sa'``         — deterministic 1-bit sense-amp PS (baseline).

Widths are scalable (``width_mult``) so the same definition serves the
paper-sized network (16/32/64) and the CPU-budget reduced network.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import stox_layers as sl
from .kernels.ref import StoxConfig
from .kernels import rng as stox_rng


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Full specification of a StoX-Net model variant."""

    name: str = "stox-resnet20"
    num_classes: int = 10
    in_channels: int = 3
    image_size: int = 16
    base_width: int = 16
    width_mult: float = 1.0
    blocks_per_stage: int = 3
    stox: StoxConfig = StoxConfig()
    first_layer: str = "hpf"  # 'hpf' | 'qf'
    first_layer_samples: int = 8
    first_layer_mode: Optional[str] = None  # None -> stox.mode; 'sa' for 1b-SA QF
    layer_samples: Optional[tuple[tuple[int, int], ...]] = None  # (layer, n)

    def widths(self) -> tuple[int, int, int]:
        w = max(4, int(round(self.base_width * self.width_mult)))
        return (w, 2 * w, 4 * w)

    def n_stox_layers(self) -> int:
        """Stochastic conv layers: conv1 (if qf) + 2 per block."""
        n = 2 * 3 * self.blocks_per_stage
        return n + (1 if self.first_layer == "qf" else 0)

    def layer_cfg(self, layer_idx: int) -> StoxConfig:
        """StoxConfig for stochastic layer ``layer_idx`` (0 = conv-1 slot).

        Layer 0 is conv-1: in QF models it gets ``first_layer_samples`` and
        (optionally) its own mode; HPF models never ask for layer 0.
        """
        cfg = self.stox
        if layer_idx == 0 and self.first_layer == "qf":
            mode = self.first_layer_mode or cfg.mode
            return dataclasses.replace(
                cfg, n_samples=self.first_layer_samples, mode=mode
            )
        if self.layer_samples is not None:
            for li, n in self.layer_samples:
                if li == layer_idx:
                    return dataclasses.replace(cfg, n_samples=n)
        return cfg


def _layer_seed(step_seed, layer_idx: int):
    """Independent stochastic-sampling stream per (step, layer)."""
    return stox_rng.mix32(
        jnp.asarray(step_seed, jnp.uint32) ^ jnp.uint32(0xA511E9B3 + layer_idx)
    )


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = (2.0 / fan_in) ** 0.5
    return std * jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)


def init_params(spec: ModelSpec, key) -> tuple[dict, dict]:
    """Returns (params, bn_states) pytrees for the spec."""
    w1, w2, w3 = spec.widths()
    keys = iter(jax.random.split(key, 64))
    params: dict = {}
    states: dict = {}

    params["conv1"] = _conv_init(next(keys), 3, 3, spec.in_channels, w1)
    params["bn1"], states["bn1"] = sl.bn_init(w1)

    stage_widths = [w1, w2, w3]
    params["stages"] = []
    states["stages"] = []
    cin = w1
    for s, cout in enumerate(stage_widths):
        blocks_p, blocks_s = [], []
        for b in range(spec.blocks_per_stage):
            bp: dict = {
                "conv1": _conv_init(next(keys), 3, 3, cin, cout),
                "conv2": _conv_init(next(keys), 3, 3, cout, cout),
            }
            bs: dict = {}
            bp["bn1"], bs["bn1"] = sl.bn_init(cout)
            bp["bn2"], bs["bn2"] = sl.bn_init(cout)
            blocks_p.append(bp)
            blocks_s.append(bs)
            cin = cout
        params["stages"].append(blocks_p)
        states["stages"].append(blocks_s)

    params["fc_w"] = 0.01 * jax.random.normal(
        next(keys), (w3, spec.num_classes), jnp.float32
    )
    params["fc_b"] = jnp.zeros((spec.num_classes,), jnp.float32)
    return params, states


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _shortcut(x, cout: int, stride: int):
    """Parameter-free ResNet-20 shortcut: strided subsample + zero-pad."""
    if stride > 1:
        x = x[:, ::stride, ::stride, :]
    cin = x.shape[-1]
    if cin < cout:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cout - cin)))
    return x


def forward(
    params: dict,
    states: dict,
    x: jnp.ndarray,
    spec: ModelSpec,
    train: bool = False,
    step_seed=0,
    use_pallas: bool = False,
):
    """Run the model; returns (logits, new_bn_states).

    ``step_seed`` decorrelates the stochastic MTJ sampling across training
    steps; at inference it selects the sampling noise realization.
    """
    new_states: dict = {"stages": []}
    layer_idx = 0

    if spec.first_layer == "qf":
        cfg = spec.layer_cfg(0)
        h = sl.stox_conv2d(
            sl.act_clip(x), params["conv1"], _layer_seed(step_seed, 0), cfg,
            use_pallas=use_pallas,
        )
    else:
        h = sl.fp_conv2d(x, params["conv1"])
    layer_idx += 1
    h, new_states["bn1"] = sl.batch_norm(h, params["bn1"], states["bn1"], train)

    for s, blocks in enumerate(params["stages"]):
        stage_states = []
        for b, bp in enumerate(blocks):
            bs = states["stages"][s][b]
            nbs: dict = {}
            stride = 2 if (s > 0 and b == 0) else 1
            cout = bp["conv1"].shape[-1]

            out = sl.stox_conv2d(
                sl.act_clip(h), bp["conv1"],
                _layer_seed(step_seed, layer_idx), spec.layer_cfg(layer_idx),
                stride=stride, use_pallas=use_pallas,
            )
            layer_idx += 1
            out, nbs["bn1"] = sl.batch_norm(out, bp["bn1"], bs["bn1"], train)

            out = sl.stox_conv2d(
                sl.act_clip(out), bp["conv2"],
                _layer_seed(step_seed, layer_idx), spec.layer_cfg(layer_idx),
                use_pallas=use_pallas,
            )
            layer_idx += 1
            out, nbs["bn2"] = sl.batch_norm(out, bp["bn2"], bs["bn2"], train)

            h = out + _shortcut(h, cout, stride)
            stage_states.append(nbs)
        new_states["stages"].append(stage_states)

    h = h.mean(axis=(1, 2))  # global average pool
    logits = h @ params["fc_w"] + params["fc_b"]
    return logits, new_states


def num_params(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Layer shape inventory (consumed by the Rust arch model via manifest.json)
# ---------------------------------------------------------------------------


def conv_layer_shapes(spec: ModelSpec) -> list[dict]:
    """Enumerate every conv/fc layer with its MVM dimensions.

    Each entry: {name, kh, kw, cin, cout, h_out, w_out, stride, stochastic}
    — exactly what ``rust/src/arch/mapper.rs`` needs to count crossbar
    instances and conversions for this workload.
    """
    w1, w2, w3 = spec.widths()
    size = spec.image_size
    layers = [
        dict(
            name="conv1", kh=3, kw=3, cin=spec.in_channels, cout=w1,
            h_out=size, w_out=size, stride=1,
            stochastic=spec.first_layer == "qf",
        )
    ]
    cin, cur = w1, size
    for s, cout in enumerate((w1, w2, w3)):
        for b in range(spec.blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            cur = cur // stride
            layers.append(
                dict(
                    name=f"s{s}b{b}c1", kh=3, kw=3, cin=cin, cout=cout,
                    h_out=cur, w_out=cur, stride=stride, stochastic=True,
                )
            )
            layers.append(
                dict(
                    name=f"s{s}b{b}c2", kh=3, kw=3, cin=cout, cout=cout,
                    h_out=cur, w_out=cur, stride=1, stochastic=True,
                )
            )
            cin = cout
    layers.append(
        dict(
            name="fc", kh=1, kw=1, cin=w3, cout=spec.num_classes,
            h_out=1, w_out=1, stride=1, stochastic=False,
        )
    )
    return layers
