"""L2 StoX-Net layers: PS-quantization-aware matmul/conv with STE backward.

Forward is the *exact* hardware model of Algorithm 1 (``kernels.ref`` /
``kernels.stox``): quantize → bit-slice/stream → per-subarray partial sums →
stochastic MTJ conversion → shift-and-add → normalize.

Backward implements the paper's Eq. 2–5: the stochastic MTJ is a
straight-through estimator and the digit decomposition / S&A collapse to a
well-defined linear chain, so the gradient is the VJP of the *collapsed
surrogate*

    O_surr(a, w) = (1/K) Σ_k  T( α · (a_q[k] @ w_q[k]) / r_arr )

with ``T = tanh`` for the stochastic MTJ (its derivative supplies the
paper's "clamp outside the saturation region") and ``T = hardtanh`` for the
deterministic 1-bit sense amp, and with STE quantizers on ``a`` and ``w``.
This is exactly the reduction the paper derives in Eq. 5.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.ref import StoxConfig
from .kernels import stox as stox_kernels


# ---------------------------------------------------------------------------
# STE quantizers
# ---------------------------------------------------------------------------


def ste_quantize_unit(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize [-1,1] to 2^bits levels with a straight-through gradient.

    Gradient is identity inside [-1,1] and zero outside (the hard clip).
    """
    xc = jnp.clip(x, -1.0, 1.0)
    xq = ref.dequantize_unit(ref.quantize_unit(xc, bits), bits)
    return xc + jax.lax.stop_gradient(xq - xc)


def normalize_weights(w: jnp.ndarray) -> jnp.ndarray:
    """Map raw weights into [-1,1] for crossbar programming.

    Per-tensor max-abs scaling; the scale is a stop-gradient constant per
    step (it is absorbed by the following BatchNorm at inference).
    """
    scale = jax.lax.stop_gradient(jnp.max(jnp.abs(w)) + 1e-8)
    return w / scale


# ---------------------------------------------------------------------------
# Collapsed surrogate (backward path, Eq. 5)
# ---------------------------------------------------------------------------


def _surrogate_mvm(a: jnp.ndarray, w: jnp.ndarray, cfg: StoxConfig) -> jnp.ndarray:
    """Differentiable collapsed forward used only for its VJP."""
    b_sz, m = a.shape
    n = w.shape[1]
    n_arrs = cfg.n_arrs(m)
    m_pad = n_arrs * cfg.r_arr

    aq = ste_quantize_unit(a, cfg.a_bits)
    wq = ste_quantize_unit(w, cfg.w_bits)
    if m_pad != m:
        aq = jnp.pad(aq, ((0, 0), (0, m_pad - m)))
        wq = jnp.pad(wq, ((0, m_pad - m), (0, 0)))
    aq = aq.reshape(b_sz, n_arrs, cfg.r_arr)
    wq = wq.reshape(n_arrs, cfg.r_arr, n)

    ps = jnp.einsum("bkr,krn->bkn", aq, wq) / float(cfg.r_arr)
    if cfg.mode == "sa":
        conv = jnp.clip(cfg.alpha * ps, -1.0, 1.0)  # hardtanh STE of sign()
    elif cfg.mode == "ideal":
        conv = ps
    else:  # "stox" / "expected": device tanh; derivative = saturation clamp
        conv = jnp.tanh(cfg.alpha * ps)
    return conv.mean(axis=1)  # 1/K Σ_k


# ---------------------------------------------------------------------------
# Hardware-aware matmul with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def stox_matmul(a, w, seed, cfg: StoxConfig, use_pallas: bool = False):
    """Hardware-exact StoX MVM with the Eq. 5 surrogate gradient.

    a: [B, M] pre-activation in [-1,1]; w: [M, N] normalized weights;
    seed: uint32 scalar (fresh per step/layer for stochastic sampling).
    """
    if use_pallas:
        return stox_kernels.stox_mvm_pallas(a, w, cfg, seed)
    return ref.stox_mvm(a, w, cfg, seed)


def _stox_matmul_fwd(a, w, seed, cfg: StoxConfig, use_pallas: bool):
    out = stox_matmul(a, w, seed, cfg, use_pallas)
    return out, (a, w)


def _stox_matmul_bwd(cfg: StoxConfig, use_pallas: bool, res, g):
    a, w = res
    _, vjp = jax.vjp(lambda a_, w_: _surrogate_mvm(a_, w_, cfg), a, w)
    ga, gw = vjp(g)
    return ga, gw, None


stox_matmul.defvjp(_stox_matmul_fwd, _stox_matmul_bwd)


# ---------------------------------------------------------------------------
# Convolution on top of the crossbar matmul (im2col lowering, Algorithm 1's
# K_h·K_w·C_in row mapping)
# ---------------------------------------------------------------------------


def _im2col(x: jnp.ndarray, kh: int, kw: int, stride: int, pad: int):
    """x: [B, H, W, C] -> patches [B, Ho, Wo, kh*kw*C]."""
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches yields channel-major (C, kh, kw) feature
    # order; reorder to (kh, kw, C) to match the row mapping used by the
    # Rust mapper and DESIGN.md (rows = K_h·K_w·C_in).
    b, ho, wo, _ = patches.shape
    c = x.shape[-1]
    patches = patches.reshape(b, ho, wo, c, kh * kw)
    patches = jnp.swapaxes(patches, 3, 4)
    return patches.reshape(b, ho, wo, kh * kw * c)


def stox_conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    seed,
    cfg: StoxConfig,
    stride: int = 1,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Crossbar-mapped 3×3/1×1 convolution (SAME padding).

    x: [B, H, W, Cin] in [-1,1]; w: [kh, kw, Cin, Cout] raw weights.
    Returns [B, Ho, Wo, Cout] in [-1,1] (Algorithm 1 normalization).
    """
    kh, kw, cin, cout = w.shape
    pad = (kh - 1) // 2
    patches = _im2col(x, kh, kw, stride, pad)
    b, ho, wo, m = patches.shape
    wn = normalize_weights(w).reshape(kh * kw * cin, cout)
    out = stox_matmul(patches.reshape(b * ho * wo, m), wn, seed, cfg, use_pallas)
    return out.reshape(b, ho, wo, cout)


def fp_conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Full-precision convolution (the HPF first layer)."""
    kh = w.shape[0]
    pad = (kh - 1) // 2
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


# ---------------------------------------------------------------------------
# BatchNorm (functional) + activation clipping
# ---------------------------------------------------------------------------


def bn_init(c: int):
    return {
        "gamma": jnp.ones((c,), jnp.float32),
        "beta": jnp.zeros((c,), jnp.float32),
    }, {
        "mean": jnp.zeros((c,), jnp.float32),
        "var": jnp.ones((c,), jnp.float32),
    }


def batch_norm(x, params, state: dict, train: bool, momentum: float = 0.9):
    """BatchNorm over all but the channel axis; returns (y, new_state)."""
    axes = tuple(range(x.ndim - 1))
    if train:
        mean = x.mean(axes)
        var = x.var(axes)
        new_state = {
            "mean": momentum * state["mean"]
            + (1 - momentum) * jax.lax.stop_gradient(mean),
            "var": momentum * state["var"]
            + (1 - momentum) * jax.lax.stop_gradient(var),
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    y = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return y * params["gamma"] + params["beta"], new_state


def act_clip(x: jnp.ndarray) -> jnp.ndarray:
    """Hardtanh: maps pre-activations into the DAC input range [-1,1]."""
    return jnp.clip(x, -1.0, 1.0)
