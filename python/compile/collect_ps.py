"""Fig. 4: distribution of normalized array-level MVM outputs (PS), for a
StoX-trained model vs a deterministic-1b-SA-trained model.

Usage (after `make train-tables`, which produces both checkpoints):

    python -m compile.collect_ps [--stox t4-hpf-1] [--sa t4-hpf-1bsa]

Prints ASCII histograms and writes `results/fig4.json` with the binned
densities. The Rust side exposes the same probe on the native crossbar
model (`stox-cli fig4`).
"""

from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from . import datasets, train
from .kernels import ref
from .stox_layers import _im2col, normalize_weights


def collect_ps(spec, params, states, xs, n_images: int = 32) -> np.ndarray:
    """Run the first stochastic conv layer over a batch and return all
    normalized PS values (the paper samples a trained layer's PS stream)."""
    x = jnp.asarray(xs[:n_images])
    w = params["conv1"] if spec.first_layer == "qf" else params["stages"][0][0]["conv1"]
    # When conv1 is HPF, probe the first stochastic layer instead (after
    # running conv1+bn to get its input); for simplicity we probe on the
    # clipped raw input for QF and on conv1 output for HPF.
    if spec.first_layer == "qf":
        inp = jnp.clip(x, -1.0, 1.0)
    else:
        from . import model as model_mod
        import jax

        # run only conv1 + bn1 to produce the first block's input
        h = jax.lax.conv_general_dilated(
            x, params["conv1"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        from . import stox_layers as sl

        h, _ = sl.batch_norm(h, params["bn1"], states["bn1"], train=False)
        inp = jnp.clip(h, -1.0, 1.0)

    kh, kw, cin, cout = w.shape
    patches = _im2col(inp, kh, kw, 1, (kh - 1) // 2)
    b, ho, wo, m = patches.shape
    wn = normalize_weights(w).reshape(kh * kw * cin, cout)
    cfg = spec.layer_cfg(1 if spec.first_layer == "hpf" else 0)
    ps = ref.partial_sums(patches.reshape(b * ho * wo, m), wn, cfg)
    return np.asarray(ps).flatten()


def histogram(vals: np.ndarray, bins: int = 41):
    h, edges = np.histogram(vals, bins=bins, range=(-1, 1), density=False)
    centers = 0.5 * (edges[:-1] + edges[1:])
    return centers, h / max(1, h.sum())


def render(centers, dens, width: int = 60) -> str:
    mx = max(dens.max(), 1e-12)
    out = []
    for c, d in zip(centers, dens):
        bar = "#" * int(round(d / mx * width))
        out.append(f"{c:+.3f} | {bar}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--stox", default="t4-hpf-1")
    ap.add_argument("--sa", default="t4-hpf-1bsa")
    ap.add_argument("--images", type=int, default=32)
    args = ap.parse_args()

    result = {}
    for label, name in [("StoX", args.stox), ("SA", args.sa)]:
        ckpt = train.CHECKPOINTS / f"{name}.pkl"
        if not ckpt.exists():
            print(f"[fig4] checkpoint {ckpt} missing — run `make train-tables`")
            continue
        spec, params, states, _ = train.load_checkpoint(ckpt)
        dataset = "digits" if spec.in_channels == 1 else "cifar"
        _, (xte, _) = datasets.get_dataset(dataset, 8, 256, spec.image_size, seed=0)
        ps = collect_ps(spec, params, states, xte, args.images)
        centers, dens = histogram(ps)
        std = float(ps.std())
        central = float(dens[np.abs(centers) < 0.25].sum())
        print(f"\n== Fig. 4 ({label}-trained, {name}): PS distribution ==")
        print(render(centers, dens))
        print(f"std {std:.4f}; mass in |ps|<0.25: {100*central:.1f}%")
        result[label] = {
            "name": name,
            "centers": centers.tolist(),
            "density": dens.tolist(),
            "std": std,
            "central_mass": central,
        }

    if {"StoX", "SA"} <= set(result):
        print(
            "\nStoX-trained spread (std {:.4f}) vs SA-trained ({:.4f}) — "
            "stochastic training {} the distribution (paper: broader, less polarized)".format(
                result["StoX"]["std"],
                result["SA"]["std"],
                "broadens"
                if result["StoX"]["std"] > result["SA"]["std"]
                else "does not broaden",
            )
        )
    out = train.RESULTS / "fig4.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
