"""Procedural stand-ins for MNIST / CIFAR-10 (no network access here).

DESIGN.md §3: the paper's accuracy claims are about *relative* behaviour of
the stochastic-PS pipeline across hardware configs, which depends on the
crossbar arithmetic and gradient flow, not on natural-image statistics.
These generators produce learnable-but-nontrivial 10-class problems:

  * ``synth_digits`` — MNIST-like: 5×7 bitmap glyphs of the digits 0–9,
    randomly shifted/scaled, with pixel noise and intensity jitter.
    Grayscale, default 16×16 (28×28 available).
  * ``synth_cifar``  — CIFAR-like: each class is a (foreground shape,
    texture frequency, color pair) signature rendered in RGB with random
    phase, position and additive noise.  Default 16×16 (32×32 available).

Images are float32 in [-1, 1], NHWC; labels are int32.
"""

from __future__ import annotations

import numpy as np

# 5x7 bitmap font for digits 0-9 (columns LSB at top), classic hex font.
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}

_GLYPHS = np.stack(
    [
        np.array([[int(c) for c in row] for row in _FONT[d]], dtype=np.float32)
        for d in range(10)
    ]
)  # [10, 7, 5]


def synth_digits(
    n: int, size: int = 16, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """MNIST-like synthetic digit dataset: ([n,size,size,1] in [-1,1], [n])."""
    rs = np.random.RandomState(seed)
    labels = rs.randint(0, 10, size=n).astype(np.int32)
    imgs = np.zeros((n, size, size, 1), np.float32)
    # upscale factor so the glyph fills most of the canvas (MNIST digits are
    # roughly centered; jitter is a couple of pixels, not full-canvas)
    up = max(1, (size - 2) // 7)
    gh, gw = 7 * up, 5 * up
    cy, cx = (size - gh) // 2, (size - gw) // 2
    max_jy, max_jx = min(2, cy), min(2, cx)
    for idx in range(n):
        g = _GLYPHS[labels[idx]]
        g = np.kron(g, np.ones((up, up), np.float32))
        # random thinning/thickening via threshold jitter then noise
        intensity = rs.uniform(0.7, 1.0)
        canvas = np.zeros((size, size), np.float32)
        dy = cy + rs.randint(-max_jy, max_jy + 1)
        dx = cx + rs.randint(-max_jx, max_jx + 1)
        canvas[dy : dy + gh, dx : dx + gw] = g * intensity
        canvas += rs.normal(0.0, 0.08, canvas.shape).astype(np.float32)
        imgs[idx, :, :, 0] = canvas
    return np.clip(imgs * 2.0 - 1.0, -1.0, 1.0), labels


# Class signatures for synth-cifar: (shape, fx, fy, fg RGB, bg RGB)
_SHAPES = ("disk", "square", "cross", "stripeh", "stripev")
_CIFAR_SIG = [
    (_SHAPES[k % 5], 1 + k % 3, 1 + (k // 2) % 3) for k in range(10)
]
_FG = np.array(
    [
        [0.9, 0.2, 0.2], [0.2, 0.9, 0.2], [0.2, 0.2, 0.9], [0.9, 0.9, 0.2],
        [0.9, 0.2, 0.9], [0.2, 0.9, 0.9], [0.9, 0.6, 0.2], [0.6, 0.2, 0.9],
        [0.5, 0.9, 0.5], [0.9, 0.5, 0.5],
    ],
    np.float32,
)
_BG = np.roll(_FG, 3, axis=0) * 0.5


def _shape_mask(shape: str, size: int, cy: float, cx: float, r: float):
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    if shape == "disk":
        return ((yy - cy) ** 2 + (xx - cx) ** 2 <= r * r).astype(np.float32)
    if shape == "square":
        return (
            (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
        ).astype(np.float32)
    if shape == "cross":
        return (
            (np.abs(yy - cy) <= r / 2.5) | (np.abs(xx - cx) <= r / 2.5)
        ).astype(np.float32) * (
            (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
        )
    if shape == "stripeh":
        return (np.floor((yy - cy) / max(r / 2, 1)) % 2 == 0).astype(np.float32)
    return (np.floor((xx - cx) / max(r / 2, 1)) % 2 == 0).astype(np.float32)


def synth_cifar(
    n: int, size: int = 16, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """CIFAR-like synthetic RGB dataset: ([n,size,size,3] in [-1,1], [n])."""
    rs = np.random.RandomState(seed)
    labels = rs.randint(0, 10, size=n).astype(np.int32)
    imgs = np.zeros((n, size, size, 3), np.float32)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    for idx in range(n):
        k = labels[idx]
        shape, fx, fy = _CIFAR_SIG[k]
        cy = size / 2 + rs.uniform(-size / 6, size / 6)
        cx = size / 2 + rs.uniform(-size / 6, size / 6)
        r = size * rs.uniform(0.22, 0.34)
        mask = _shape_mask(shape, size, cy, cx, r)
        phase = rs.uniform(0, 2 * np.pi, 2)
        tex = 0.5 + 0.5 * np.sin(
            2 * np.pi * fx * xx / size + phase[0]
        ) * np.sin(2 * np.pi * fy * yy / size + phase[1])
        fg = _FG[k] * rs.uniform(0.8, 1.2)
        bg = _BG[k] * rs.uniform(0.8, 1.2)
        img = (
            mask[..., None] * fg[None, None, :] * (0.55 + 0.45 * tex[..., None])
            + (1 - mask[..., None]) * bg[None, None, :] * (0.7 + 0.3 * tex[..., None])
        )
        img += rs.normal(0.0, 0.06, img.shape)
        imgs[idx] = img
    return np.clip(imgs * 2.0 - 1.0, -1.0, 1.0).astype(np.float32), labels


def get_dataset(name: str, n_train: int, n_test: int, size: int, seed: int = 0):
    """Returns ((x_train, y_train), (x_test, y_test)) for 'digits'|'cifar'."""
    gen = {"digits": synth_digits, "cifar": synth_cifar}[name]
    xtr, ytr = gen(n_train, size=size, seed=seed)
    xte, yte = gen(n_test, size=size, seed=seed + 10_000)
    return (xtr, ytr), (xte, yte)
