"""Pure-jnp oracle for the StoX-Net stochastic crossbar MVM (Algorithm 1).

This file is the *semantic definition* of the crossbar arithmetic.  The
Pallas kernel (``stox.py``), the L2 layers (``stox_layers.py``) and the Rust
functional crossbar (``rust/src/imc/mvm.rs``) are all tested against it.

Arithmetic (documented in DESIGN.md §2):

  * activations ``a`` in [-1, 1] are quantized to ``a_bits`` levels:
    ``u = round((a+1)/2 * (2^Ab - 1))`` and decomposed into base-``2^As``
    signed digits ``x_i = 2 d_i - (2^As - 1)`` so that
    ``a_q = sum_i 2^{i As} x_i / (2^Ab - 1)``  (bit streaming, DAC side);
  * weights ``w`` in [-1, 1] likewise into ``w_bits`` / ``2^Ws`` signed
    slice digits ``t_j`` (bit slicing; two memory cells per weight give the
    signed differential column current);
  * the row dimension is partitioned into ``n_arrs = ceil(M / r_arr)``
    subarrays; each (subarray k, stream i, slice j) produces an analog
    partial sum ``PS[k,i,j] = sum_rows x_i t_j`` — the column current;
  * the stochastic SOT-MTJ converts ``PS`` to ±1 with
    ``P(+1) = (tanh(alpha * PS / r_arr) + 1)/2`` (Eq. 1), read
    ``n_samples`` times and counted;
  * counts are shift-and-added with scale ``2^{i As + j Ws}`` and
    normalized by ``(2^Ab-1)(2^Wb-1) * n_arrs * n_samples`` so the MVM
    output lands in [-1, 1] (Algorithm 1's final normalization).
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from . import rng

# Modes every backend implements (the Pallas kernel included — test_kernel
# parametrizes its parity suite over this tuple).
MODES = ("stox", "sa", "expected", "ideal")
# Oracle-only converter modes (the Rust registry implements them; golden
# vectors pin the Rust side against this oracle — gen_golden.py).
ORACLE_ONLY_MODES = ("sparse", "inhomo")
ALL_MODES = MODES + ORACLE_ONLY_MODES


@dataclasses.dataclass(frozen=True)
class StoxConfig:
    """Hardware configuration of one StoX crossbar-mapped MVM.

    Mirrors the paper's ``XwYaZbs`` naming: ``w_bits`` = X, ``a_bits`` = Y,
    ``w_slice_bits`` = Z.  ``a_stream_bits`` is the DAC resolution (1 in the
    paper).  ``mode``:

      * ``"stox"``     — stochastic MTJ sampling (Eq. 1), ``n_samples`` reads
      * ``"sa"``       — deterministic 1-bit sense amplifier (alpha → inf)
      * ``"expected"`` — infinite-sample limit, PS → tanh(alpha·ps)
      * ``"ideal"``    — no PS quantization at all (full-precision ADC)
      * ``"sparse"``   — sparsity-aware low-bit ADC (``sparse_bits``): column
        slices whose partial sums are all exactly zero skip conversion,
        everything else is midtread-quantized (Rust ``SparseAdcConv``)
      * ``"inhomo"``   — §3.2.3 inhomogeneous MTJ sampling: the read count
        of a (stream i, slice j) group grows linearly with its bit
        significance, from ``base_samples`` at the LSB to ``base_samples +
        extra_samples`` at the MSB; outputs are normalized sample means
        (Rust ``InhomogeneousMtjConv``)
    """

    a_bits: int = 4
    w_bits: int = 4
    a_stream_bits: int = 1
    w_slice_bits: int = 4
    r_arr: int = 256
    n_samples: int = 1
    alpha: float = 4.0
    mode: str = "stox"
    # sparse-ADC resolution (mode == "sparse")
    sparse_bits: int = 4
    # inhomogeneous sampling range (mode == "inhomo")
    base_samples: int = 1
    extra_samples: int = 3

    def __post_init__(self):
        if self.a_bits % self.a_stream_bits != 0:
            raise ValueError("a_bits must be divisible by a_stream_bits")
        if self.w_bits % self.w_slice_bits != 0:
            raise ValueError("w_bits must be divisible by w_slice_bits")
        if self.mode not in ALL_MODES:
            raise ValueError(f"mode must be one of {ALL_MODES}")
        if self.n_samples < 1:
            raise ValueError("n_samples >= 1")
        if self.r_arr < 1:
            raise ValueError("r_arr >= 1")
        if not 1 <= self.sparse_bits <= 16:
            raise ValueError("sparse_bits in 1..=16")
        if self.base_samples < 1:
            raise ValueError("base_samples >= 1")
        if self.extra_samples < 0:
            raise ValueError("extra_samples >= 0")

    @property
    def n_streams(self) -> int:
        return self.a_bits // self.a_stream_bits

    @property
    def n_slices(self) -> int:
        return self.w_bits // self.w_slice_bits

    def n_arrs(self, m: int) -> int:
        return max(1, math.ceil(m / self.r_arr))

    @property
    def tag(self) -> str:
        return f"{self.w_bits}w{self.a_bits}a{self.w_slice_bits}bs"


# ---------------------------------------------------------------------------
# Quantization / digit decomposition
# ---------------------------------------------------------------------------


def quantize_unit(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric uniform quantizer of [-1,1] onto 2^bits levels.

    Returns the *integer code* ``u`` in [0, 2^bits - 1]; the represented
    value is ``2 u / (2^bits - 1) - 1``.
    """
    levels = (1 << bits) - 1
    x = jnp.clip(x, -1.0, 1.0)
    return jnp.round((x + 1.0) * 0.5 * levels).astype(jnp.int32)


def dequantize_unit(u: jnp.ndarray, bits: int) -> jnp.ndarray:
    levels = (1 << bits) - 1
    return 2.0 * u.astype(jnp.float32) / levels - 1.0


def signed_digits(u: jnp.ndarray, bits: int, digit_bits: int) -> jnp.ndarray:
    """Decompose integer codes into signed base-2^digit_bits digits.

    Output has a trailing axis of length ``bits // digit_bits`` with
    digit ``x_i = 2 d_i - (2^digit_bits - 1)`` (±1 for 1-bit digits),
    ordered least-significant first, as float32 (these are the physical
    DAC levels / differential cell currents).
    """
    n_digits = bits // digit_bits
    base = 1 << digit_bits
    shifts = jnp.arange(n_digits, dtype=jnp.int32) * digit_bits
    d = (u[..., None] >> shifts) & (base - 1)
    return (2 * d - (base - 1)).astype(jnp.float32)


def digit_scales(bits: int, digit_bits: int) -> jnp.ndarray:
    """Shift-and-add scales 2^{i*digit_bits}, LSB first."""
    n_digits = bits // digit_bits
    return jnp.asarray(
        [float(1 << (i * digit_bits)) for i in range(n_digits)], jnp.float32
    )


# ---------------------------------------------------------------------------
# Stochastic MTJ conversion
# ---------------------------------------------------------------------------


def mtj_probability(ps_norm: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """P(read +1) of the SOT-MTJ for a normalized column current (Eq. 1)."""
    return 0.5 * (jnp.tanh(alpha * ps_norm) + 1.0)


def mtj_sample_counts(
    ps_norm: jnp.ndarray,
    alpha: float,
    n_samples: int,
    seed,
    counter_base: jnp.ndarray,
) -> jnp.ndarray:
    """Sum of ``n_samples`` stochastic ±1 MTJ reads for each PS element.

    ``counter_base`` assigns each PS element a unique event-counter base;
    sample ``s`` of element ``e`` uses counter ``base[e] * n_samples + s``,
    identically to the Rust functional simulator.
    """
    p = mtj_probability(ps_norm, alpha)
    total = jnp.zeros_like(ps_norm)
    for s in range(n_samples):
        c = counter_base * jnp.uint32(n_samples) + jnp.uint32(s)
        u = rng.uniform01(seed, c)
        total = total + jnp.where(u < p, 1.0, -1.0)
    return total


# ---------------------------------------------------------------------------
# Full Algorithm 1
# ---------------------------------------------------------------------------


def _pad_rows(x: jnp.ndarray, axis_len: int, r_arr: int) -> jnp.ndarray:
    """Zero-pad axis 0 (crossbar rows) to a multiple of r_arr.

    Padding happens in the *digit/current* domain where an absent cell
    contributes exactly zero column current, so padded rows are inert.
    """
    n_arrs = max(1, math.ceil(axis_len / r_arr))
    pad = n_arrs * r_arr - axis_len
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths)


def partial_sums(a: jnp.ndarray, w: jnp.ndarray, cfg: StoxConfig) -> jnp.ndarray:
    """Analog array-level partial sums, normalized by r_arr.

    a: [B, M] activations in [-1,1];  w: [M, N] weights in [-1,1].
    Returns float32 [B, n_arrs, N, n_streams, n_slices] in [-1, 1].
    """
    b_sz, m = a.shape
    m2, n = w.shape
    assert m == m2, (m, m2)
    n_arrs = cfg.n_arrs(m)

    ua = quantize_unit(a, cfg.a_bits)
    uw = quantize_unit(w, cfg.w_bits)
    xd = signed_digits(ua, cfg.a_bits, cfg.a_stream_bits)  # [B, M, I]
    td = signed_digits(uw, cfg.w_bits, cfg.w_slice_bits)  # [M, N, J]

    xd = _pad_rows(jnp.swapaxes(xd, 0, 1), m, cfg.r_arr)  # [Mp, B, I]
    td = _pad_rows(td, m, cfg.r_arr)  # [Mp, N, J]
    xd = xd.reshape(n_arrs, cfg.r_arr, b_sz, cfg.n_streams)
    td = td.reshape(n_arrs, cfg.r_arr, n, cfg.n_slices)

    # PS[b, k, n, i, j] = sum_r xd[k, r, b, i] * td[k, r, n, j]
    ps = jnp.einsum("krbi,krnj->bknij", xd, td)
    return ps / float(cfg.r_arr)


def ps_counter_base(
    b_sz: int, n_arrs: int, n_cols: int, cfg: StoxConfig
) -> jnp.ndarray:
    """Canonical event-counter base for each PS element.

    Layout (row-major over [B, K, N, I, J]) — shared with the Rust side:
      base = (((b * K + k) * N + n) * I + i) * J + j
    """
    total = b_sz * n_arrs * n_cols * cfg.n_streams * cfg.n_slices
    return jnp.arange(total, dtype=jnp.uint32).reshape(
        b_sz, n_arrs, n_cols, cfg.n_streams, cfg.n_slices
    )


def quant_midtread(ps: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Midtread uniform quantizer over [-1, 1] (N-bit SAR ADC readout).

    Expression-identical with the Rust ``quant_midtread`` (``2·u/levels −
    1``, round-half-even): same f32 operations, same bits.
    """
    levels = jnp.float32((1 << bits) - 1)
    u = jnp.round((jnp.clip(ps, -1.0, 1.0) + 1.0) * 0.5 * levels)
    return 2.0 * u / levels - 1.0


def sparse_adc_convert(ps: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Sparsity-aware low-bit ADC (Rust ``SparseAdcConv``).

    A column slice is one (b, k, i, j) group over the N output columns;
    groups whose partial sums are all exactly zero skip conversion (output
    0, no ADC action), everything else quantizes like the plain N-bit ADC.
    """
    # ps: [B, K, N, I, J]; the column-slice axis is N (axis 2)
    zero_group = jnp.all(ps == 0.0, axis=2, keepdims=True)
    return jnp.where(zero_group, jnp.float32(0.0), quant_midtread(ps, bits))


def inhomo_sample_table(cfg: StoxConfig) -> list[list[int]]:
    """Per-(stream i, slice j) read counts of §3.2.3 inhomogeneous sampling.

    ``n(i, j) = base + round(extra · sig(i, j) / sig_max)`` with
    ``sig = i·a_stream_bits + j·w_slice_bits`` — round half *away from
    zero*, matching the Rust ``InhomogeneousMtjConv::new`` (f64 ``round``).
    """
    i_n, j_n = cfg.n_streams, cfg.n_slices
    da, dw = cfg.a_stream_bits, cfg.w_slice_bits
    base = max(1, cfg.base_samples)
    sig_max = (i_n - 1) * da + (j_n - 1) * dw
    table = []
    for i in range(i_n):
        row = []
        for j in range(j_n):
            sig = i * da + j * dw
            if sig_max == 0:
                n = base + cfg.extra_samples
            else:
                n = base + int(
                    math.floor(cfg.extra_samples * sig / sig_max + 0.5)
                )
            row.append(max(1, n))
        table.append(row)
    return table


def inhomo_convert(
    ps: jnp.ndarray, cfg: StoxConfig, seed, counter_base: jnp.ndarray
) -> jnp.ndarray:
    """§3.2.3 inhomogeneous MTJ sampling (Rust ``InhomogeneousMtjConv``).

    Each (stream, slice) group draws its own ``n(i, j)`` reads; element
    counters advance in blocks of ``n_max = base + extra`` so every group
    owns a disjoint counter range (no draw reused), and outputs are
    normalized sample means so the shift-and-add normalization stays
    uniform (samples = 1).
    """
    table = inhomo_sample_table(cfg)
    n_max = max(1, cfg.base_samples) + cfg.extra_samples
    p = mtj_probability(ps, cfg.alpha)
    out = jnp.zeros_like(ps)
    for i in range(cfg.n_streams):
        for j in range(cfg.n_slices):
            n_ij = table[i][j]
            total = jnp.zeros_like(ps[..., i, j])
            for s in range(n_ij):
                c = counter_base[..., i, j] * jnp.uint32(n_max) + jnp.uint32(s)
                u = rng.uniform01(seed, c)
                total = total + jnp.where(u < p[..., i, j], 1.0, -1.0)
            # reciprocal multiply, not division — bitwise what Rust does
            inv = jnp.float32(1.0) / jnp.float32(n_ij)
            out = out.at[..., i, j].set(total * inv)
    return out


def convert_ps(
    ps: jnp.ndarray, cfg: StoxConfig, seed, counter_base: jnp.ndarray | None
) -> tuple[jnp.ndarray, int]:
    """Apply the configured PS converter; returns (converted, samples)."""
    if cfg.mode == "ideal":
        return ps, 1
    if cfg.mode == "expected":
        return jnp.tanh(cfg.alpha * ps), 1
    if cfg.mode == "sa":
        return jnp.where(ps >= 0.0, 1.0, -1.0), 1
    if cfg.mode == "sparse":
        return sparse_adc_convert(ps, cfg.sparse_bits), 1
    if cfg.mode == "inhomo":
        assert counter_base is not None
        return inhomo_convert(ps, cfg, seed, counter_base), 1
    assert counter_base is not None
    conv = mtj_sample_counts(ps, cfg.alpha, cfg.n_samples, seed, counter_base)
    return conv, cfg.n_samples


def shift_and_add(conv: jnp.ndarray, cfg: StoxConfig, samples: int) -> jnp.ndarray:
    """S&A recombination + Algorithm 1 output normalization to [-1, 1].

    conv: [B, K, N, I, J] converted PS (counts or analog); returns [B, N].
    """
    n_arrs = conv.shape[1]
    sa = digit_scales(cfg.a_bits, cfg.a_stream_bits)  # [I]
    sw = digit_scales(cfg.w_bits, cfg.w_slice_bits)  # [J]
    lev = float(((1 << cfg.a_bits) - 1) * ((1 << cfg.w_bits) - 1))
    out = jnp.einsum("bknij,i,j->bn", conv, sa, sw)
    return out / (lev * n_arrs * samples)


def stox_mvm(a: jnp.ndarray, w: jnp.ndarray, cfg: StoxConfig, seed=0) -> jnp.ndarray:
    """Hardware-aware MVM output O_l in [-1, 1] per Algorithm 1.

    a: [B, M] in [-1,1];  w: [M, N] in [-1,1].  Returns [B, N] float32.
    """
    b_sz, m = a.shape
    n = w.shape[1]
    ps = partial_sums(a, w, cfg)
    base = (
        ps_counter_base(b_sz, cfg.n_arrs(m), n, cfg)
        if cfg.mode in ("stox", "inhomo")
        else None
    )
    conv, samples = convert_ps(ps, cfg, seed, base)
    return shift_and_add(conv, cfg, samples)


def ideal_mvm(a: jnp.ndarray, w: jnp.ndarray, cfg: StoxConfig) -> jnp.ndarray:
    """Quantized-but-unconverted MVM (infinite-precision ADC readout).

    The convergence target of the stochastic path in the linear tanh
    region; also the error-free reference for the sensitivity analysis.
    """
    return stox_mvm(a, w, dataclasses.replace(cfg, mode="ideal"))
