"""L1 Pallas kernels for the StoX-Net stochastic crossbar MVM.

The hot-spot of the paper is Algorithm 1: a bit-sliced / bit-streamed
matrix-vector product whose array-level partial sums are converted to
digital by stochastic SOT-MTJ sampling, then shift-and-added.

TPU mapping (DESIGN.md §Hardware-Adaptation): one crossbar subarray
(``r_arr`` rows) is one grid step; its digit matrices are staged
HBM→VMEM by the BlockSpecs exactly as the paper stages operands into the
analog array.  The digit contraction is expressed as a single
``[B·I, R] @ [R, N·J]`` matmul so it lands on the MXU; the stochastic
conversion is elementwise VPU work on the PS tile; the subarray axis is
the innermost grid dimension so the output tile is revisited
consecutively (legal accumulation on real TPU, no spills).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU utilization is *estimated* in DESIGN.md §7 from
the VMEM footprint / MXU shapes chosen here.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import StoxConfig

# Column tile: one MXU-native lane group. Subarrays are whole (the paper's
# conversion granularity); batch rides along in the sublane dimension.
DEFAULT_COL_TILE = 128


def _counter_base_block(
    b_sz: int, n_tile: int, n_total: int, k: int, n_k: int, nb, cfg: StoxConfig
):
    """Event-counter bases for a [B, Nt, I, J] PS tile.

    Must match ``ref.ps_counter_base``:  base = (((b·K + k)·N + n)·I + i)·J + j.
    """
    shape = (b_sz, n_tile, cfg.n_streams, cfg.n_slices)
    bb = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    nn = jax.lax.broadcasted_iota(jnp.uint32, shape, 1) + jnp.uint32(nb) * jnp.uint32(
        n_tile
    )
    ii = jax.lax.broadcasted_iota(jnp.uint32, shape, 2)
    jj = jax.lax.broadcasted_iota(jnp.uint32, shape, 3)
    base = (
        ((bb * jnp.uint32(n_k) + jnp.uint32(k)) * jnp.uint32(n_total) + nn)
        * jnp.uint32(cfg.n_streams)
        + ii
    ) * jnp.uint32(cfg.n_slices) + jj
    return base


def _stox_mvm_kernel(
    seed_ref,
    x_ref,
    t_ref,
    o_ref,
    *,
    cfg: StoxConfig,
    n_total: int,
    n_k: int,
):
    """One grid step: subarray ``k``, output-column tile ``nb``.

    x_ref: [1, R, B, I] activation digits of subarray k
    t_ref: [1, R, Nt, J] weight-slice digits of subarray k, column tile nb
    o_ref: [B, Nt] accumulated MVM output tile
    """
    nb = pl.program_id(0)
    k = pl.program_id(1)

    x = x_ref[0]  # [R, B, I]
    t = t_ref[0]  # [R, Nt, J]
    r, b_sz, i_n = x.shape
    n_tile, j_n = t.shape[1], t.shape[2]

    # MXU-friendly contraction over the crossbar rows:
    #   [B*I, R] @ [R, Nt*J]  ->  PS for every (stream, slice) pair at once.
    xm = x.transpose(1, 2, 0).reshape(b_sz * i_n, r)
    tm = t.reshape(r, n_tile * j_n)
    ps = jax.lax.dot(xm, tm, preferred_element_type=jnp.float32)
    ps = ps.reshape(b_sz, i_n, n_tile, j_n).transpose(0, 2, 1, 3)  # [B,Nt,I,J]
    ps = ps * (1.0 / float(cfg.r_arr))

    if cfg.mode == "ideal":
        conv, samples = ps, 1
    elif cfg.mode == "expected":
        conv, samples = jnp.tanh(cfg.alpha * ps), 1
    elif cfg.mode == "sa":
        conv, samples = jnp.where(ps >= 0.0, 1.0, -1.0), 1
    else:  # stochastic MTJ sampling, unrolled (n_samples <= 8 in the paper)
        seed = seed_ref[0]
        base = _counter_base_block(b_sz, n_tile, n_total, k, n_k, nb, cfg)
        p = 0.5 * (jnp.tanh(cfg.alpha * ps) + 1.0)
        conv = jnp.zeros_like(ps)
        for s in range(cfg.n_samples):
            c = base * jnp.uint32(cfg.n_samples) + jnp.uint32(s)
            h = c ^ _mix32_scalar(seed ^ jnp.uint32(0x9E3779B9))
            u = (_mix32(h) >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
                1.0 / (1 << 24)
            )
            conv = conv + jnp.where(u < p, 1.0, -1.0)
        samples = cfg.n_samples

    # Shift-and-add + Algorithm 1 normalization, folded to a single scale.
    # The 2^{i·As + j·Ws} scale grid is built with iotas so the kernel stays
    # closure-free (pallas_call rejects captured array constants).
    ii = jax.lax.broadcasted_iota(jnp.float32, conv.shape, 2)
    jj = jax.lax.broadcasted_iota(jnp.float32, conv.shape, 3)
    scale = jnp.exp2(ii * float(cfg.a_stream_bits) + jj * float(cfg.w_slice_bits))
    lev = float(((1 << cfg.a_bits) - 1) * ((1 << cfg.w_bits) - 1))
    norm = 1.0 / (lev * n_k * samples)
    po = (conv * scale).sum(axis=(2, 3)) * norm

    @pl.when(k == 0)
    def _init():
        o_ref[...] = po

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += po


def _mix32(x):
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _mix32_scalar(x):
    return _mix32(jnp.asarray(x, jnp.uint32))


def prepare_digits(a: jnp.ndarray, w: jnp.ndarray, cfg: StoxConfig):
    """Quantize + decompose + partition operands for the kernel.

    Returns (xd [K, R, B, I], td [K, R, N, J]); the compile-time analogue
    of programming the crossbar (weights) and the DAC stream buffers.
    """
    b_sz, m = a.shape
    n = w.shape[1]
    n_arrs = cfg.n_arrs(m)

    ua = ref.quantize_unit(a, cfg.a_bits)
    uw = ref.quantize_unit(w, cfg.w_bits)
    xd = ref.signed_digits(ua, cfg.a_bits, cfg.a_stream_bits)  # [B, M, I]
    td = ref.signed_digits(uw, cfg.w_bits, cfg.w_slice_bits)  # [M, N, J]

    xd = ref._pad_rows(jnp.swapaxes(xd, 0, 1), m, cfg.r_arr)  # [Mp, B, I]
    td = ref._pad_rows(td, m, cfg.r_arr)  # [Mp, N, J]
    xd = xd.reshape(n_arrs, cfg.r_arr, b_sz, cfg.n_streams)
    td = td.reshape(n_arrs, cfg.r_arr, n, cfg.n_slices)
    return xd, td


def stox_mvm_pallas(
    a: jnp.ndarray,
    w: jnp.ndarray,
    cfg: StoxConfig,
    seed=0,
    col_tile: int | None = None,
) -> jnp.ndarray:
    """Pallas implementation of Algorithm 1; drop-in for ``ref.stox_mvm``."""
    b_sz, m = a.shape
    n = w.shape[1]
    n_arrs = cfg.n_arrs(m)
    xd, td = prepare_digits(a, w, cfg)

    nt = col_tile or min(DEFAULT_COL_TILE, n)
    n_pad = math.ceil(n / nt) * nt
    if n_pad != n:
        td = jnp.pad(td, ((0, 0), (0, 0), (0, n_pad - n), (0, 0)))
    n_blocks = n_pad // nt

    seed_arr = jnp.asarray([seed], jnp.uint32)
    kernel = functools.partial(
        _stox_mvm_kernel, cfg=cfg, n_total=n, n_k=n_arrs
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks, n_arrs),
        in_specs=[
            pl.BlockSpec((1,), lambda nb, k: (0,)),
            pl.BlockSpec(
                (1, cfg.r_arr, b_sz, cfg.n_streams), lambda nb, k: (k, 0, 0, 0)
            ),
            pl.BlockSpec(
                (1, cfg.r_arr, nt, cfg.n_slices), lambda nb, k: (k, 0, nb, 0)
            ),
        ],
        out_specs=pl.BlockSpec((b_sz, nt), lambda nb, k: (0, nb)),
        out_shape=jax.ShapeDtypeStruct((b_sz, n_pad), jnp.float32),
        interpret=True,
    )(seed_arr, xd, td)
    return out[:, :n]


def mtj_convert_pallas(
    ps_norm: jnp.ndarray, alpha: float, n_samples: int, seed=0
) -> jnp.ndarray:
    """Standalone stochastic MTJ converter kernel over a flat PS vector.

    Counter base is the flat element index — matches the Rust
    ``device::converter`` known-answer tests.
    """
    (n,) = ps_norm.shape
    seed_arr = jnp.asarray([seed], jnp.uint32)

    def kernel(seed_ref, ps_ref, o_ref):
        ps = ps_ref[...]
        p = 0.5 * (jnp.tanh(alpha * ps) + 1.0)
        base = jax.lax.broadcasted_iota(jnp.uint32, ps.shape, 0)
        mixed_seed = _mix32_scalar(seed_ref[0] ^ jnp.uint32(0x9E3779B9))
        total = jnp.zeros_like(ps)
        for s in range(n_samples):
            c = base * jnp.uint32(n_samples) + jnp.uint32(s)
            u = (_mix32(c ^ mixed_seed) >> jnp.uint32(8)).astype(
                jnp.float32
            ) * jnp.float32(1.0 / (1 << 24))
            total = total + jnp.where(u < p, 1.0, -1.0)
        o_ref[...] = total

    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((1,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(seed_arr, ps_norm)
