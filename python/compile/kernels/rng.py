"""Counter-based RNG shared (bit-identically) between L1/L2 python and the
Rust L3 functional simulator (``rust/src/imc/rng.rs``).

StoX-Net's stochastic MTJ conversion needs a random uniform per
(subarray, stream, slice, sample, batch, column) event.  Using a
counter-based hash makes the whole stochastic MVM a *pure function* of
``(inputs, weights, seed)`` so that

  * the Pallas kernel, the pure-jnp oracle and the Rust crossbar simulator
    produce identical bits (tested in ``python/tests`` and
    ``rust/src/imc/rng.rs`` against shared known-answer vectors), and
  * AOT-lowered artifacts stay deterministic and replayable.

The hash is the 32-bit xxhash/murmur-style avalanche finalizer applied
twice; it passes the SmallCrush-equivalent sanity checks we care about
(uniformity of the top bits, no counter-stride correlation) and costs a
handful of VPU ops per event.
"""

from __future__ import annotations

import jax.numpy as jnp

_M1 = 0x7FEB352D
_M2 = 0x846CA68B
_GOLDEN = 0x9E3779B9


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """32-bit avalanche mix (lowbias32 by E. Wellons)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> jnp.uint32(16))
    return x


def hash_counter(seed, counter: jnp.ndarray) -> jnp.ndarray:
    """Hash a (scalar) seed with an array of event counters -> uint32."""
    seed = jnp.asarray(seed, jnp.uint32)
    return mix32(counter.astype(jnp.uint32) ^ mix32(seed ^ jnp.uint32(_GOLDEN)))


def uniform01(seed, counter: jnp.ndarray) -> jnp.ndarray:
    """U[0,1) float32 from (seed, counter); bit-identical to the Rust side."""
    h = hash_counter(seed, counter)
    # f32 has a 24-bit mantissa; use the top 24 bits so that the float is
    # exactly representable and the Rust side (h >> 8) as f32 * 2^-24 matches.
    return (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
