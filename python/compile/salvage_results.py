"""Rebuild a preset's results JSON from saved checkpoints.

Every training run checkpoints `(spec, params, states, record)`; if a long
sweep is interrupted before `run_preset` writes its aggregate JSON, this
tool reconstructs it from whatever checkpoints exist:

    python -m compile.salvage_results --preset fig7
"""

from __future__ import annotations

import argparse
import json

from . import train


PREFIX = {"table3": "t3-", "table4": "t4-", "fig7": "f7"}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", required=True)
    args = ap.parse_args()
    prefix = PREFIX[args.preset]
    records = []
    for ckpt in sorted(train.CHECKPOINTS.glob(f"{prefix}*.pkl")):
        try:
            _, _, _, record = train.load_checkpoint(ckpt)
            records.append(record)
        except Exception as e:  # pragma: no cover
            print(f"skip {ckpt}: {e}")
    out = train.RESULTS / f"{args.preset}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(
        json.dumps({"preset": args.preset, "partial": True, "runs": records}, indent=1)
    )
    print(f"wrote {out} with {len(records)} runs")


if __name__ == "__main__":
    main()
