"""Export the tiny committed checkpoint fixture ``rust/tests/data/tiny_inhomo``.

The fixture is a deterministic, random-init (untrained) StoX ResNet whose
manifest selects the §3.2.3 inhomogeneous converter through an *extended
registry mode string* — ``spec.stox.mode = "inhomo:base=1,extra=3"`` —
instead of a plain built-in mode name.  The Rust side
(``rust/tests/model_sweep.rs``) loads it with **no** ``--converter``
override anywhere, pinning manifest-driven converter selection through
``PsConverterSpec::from_mode`` end-to-end (a ROADMAP follow-up of PR 1),
and reuses it as the checkpoint for the shared-weight-programming
regression tests and the ``benches/sweep.rs`` programming-reuse case.

Layout mirrors ``aot.py``'s export exactly (same jax-``keystr`` tensor
names, same ``manifest.json`` schema, minus the HLO artifacts that a
functional-model test does not need), but is numpy-only so it runs — and
reproduces byte-for-byte — anywhere.

    python -m compile.export_fixture          # from python/

Regeneration is deterministic (``np.random.RandomState``);
``python/tests/test_fixture_export.py`` pins the committed bytes against
a fresh export.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

OUT = (
    pathlib.Path(__file__).resolve().parents[2]
    / "rust"
    / "tests"
    / "data"
    / "tiny_inhomo"
)

# Tiny geometry: 8×8×3 inputs, base width 4 (stages 4/8/16), one block per
# stage — a few KiB of weights, fast enough for `cargo test` in debug.
SPEC = {
    "name": "tiny-inhomo-fixture",
    "num_classes": 10,
    "in_channels": 3,
    "image_size": 8,
    "base_width": 4,
    "width_mult": 1.0,
    "blocks_per_stage": 1,
    "stox": {
        "a_bits": 4,
        "w_bits": 4,
        "a_stream_bits": 1,
        "w_slice_bits": 4,
        "r_arr": 64,
        "n_samples": 1,
        "alpha": 4.0,
        # the point of the fixture: an extended `name:k=v,..` mode string
        # resolved by the Rust ConverterRegistry at load time
        "mode": "inhomo:base=1,extra=3",
    },
    "first_layer": "qf",
    "first_layer_samples": 2,
    "first_layer_mode": None,
    "layer_samples": None,
}

TESTSET_N = 8


def widths() -> tuple[int, int, int]:
    w = max(4, int(round(SPEC["base_width"] * SPEC["width_mult"])))
    return (w, 2 * w, 4 * w)


def conv_layer_shapes() -> list[dict]:
    """Mirror of ``model.conv_layer_shapes`` for the fixture spec."""
    w1, w2, w3 = widths()
    size = SPEC["image_size"]
    layers = [
        dict(
            name="conv1", kh=3, kw=3, cin=SPEC["in_channels"], cout=w1,
            h_out=size, w_out=size, stride=1, stochastic=True,
        )
    ]
    cin, cur = w1, size
    for s, cout in enumerate((w1, w2, w3)):
        for b in range(SPEC["blocks_per_stage"]):
            stride = 2 if (s > 0 and b == 0) else 1
            cur = cur // stride
            layers.append(
                dict(
                    name=f"s{s}b{b}c1", kh=3, kw=3, cin=cin, cout=cout,
                    h_out=cur, w_out=cur, stride=stride, stochastic=True,
                )
            )
            layers.append(
                dict(
                    name=f"s{s}b{b}c2", kh=3, kw=3, cin=cout, cout=cout,
                    h_out=cur, w_out=cur, stride=1, stochastic=True,
                )
            )
            cin = cout
    layers.append(
        dict(
            name="fc", kh=1, kw=1, cin=w3, cout=SPEC["num_classes"],
            h_out=1, w_out=1, stride=1, stochastic=False,
        )
    )
    return layers


def build_tensors(seed: int = 0) -> list[tuple[str, np.ndarray]]:
    """(jax-keystr name, float32 array) pairs, He-init convs, identity BN."""
    rs = np.random.RandomState(seed)
    w1, w2, w3 = widths()

    def conv(kh: int, kw: int, cin: int, cout: int) -> np.ndarray:
        std = (2.0 / (kh * kw * cin)) ** 0.5
        return (std * rs.randn(kh, kw, cin, cout)).astype(np.float32)

    tensors: list[tuple[str, np.ndarray]] = []

    def bn(prefix: str, c: int) -> None:
        tensors.append((f"['params']{prefix}['beta']", np.zeros(c, np.float32)))
        tensors.append((f"['params']{prefix}['gamma']", np.ones(c, np.float32)))

    def bn_state(prefix: str, c: int) -> None:
        tensors.append((f"['states']{prefix}['mean']", np.zeros(c, np.float32)))
        tensors.append((f"['states']{prefix}['var']", np.ones(c, np.float32)))

    tensors.append(("['params']['conv1']", conv(3, 3, SPEC["in_channels"], w1)))
    bn("['bn1']", w1)
    cin = w1
    for s, cout in enumerate((w1, w2, w3)):
        for b in range(SPEC["blocks_per_stage"]):
            p = f"['stages'][{s}][{b}]"
            tensors.append((f"['params']{p}['conv1']", conv(3, 3, cin, cout)))
            bn(f"{p}['bn1']", cout)
            tensors.append((f"['params']{p}['conv2']", conv(3, 3, cout, cout)))
            bn(f"{p}['bn2']", cout)
            cin = cout
    tensors.append(
        (
            "['params']['fc_w']",
            (0.1 * rs.randn(w3, SPEC["num_classes"])).astype(np.float32),
        )
    )
    tensors.append(
        ("['params']['fc_b']", np.zeros(SPEC["num_classes"], np.float32))
    )
    # BN running stats after the params, like the aot.py pytree flatten
    bn_state("['bn1']", w1)
    cin = w1
    for s, cout in enumerate((w1, w2, w3)):
        for b in range(SPEC["blocks_per_stage"]):
            p = f"['stages'][{s}][{b}]"
            bn_state(f"{p}['bn1']", cout)
            bn_state(f"{p}['bn2']", cout)
            cin = cout
    return tensors


def build_testset(seed: int = 1) -> tuple[np.ndarray, np.ndarray]:
    rs = np.random.RandomState(seed)
    size = SPEC["image_size"]
    images = rs.uniform(-1.0, 1.0, (TESTSET_N, size, size, SPEC["in_channels"]))
    labels = rs.randint(0, SPEC["num_classes"], TESTSET_N)
    return images.astype(np.float32), labels.astype(np.int32)


def export(outdir: pathlib.Path) -> dict:
    outdir.mkdir(parents=True, exist_ok=True)

    tensors = build_tensors()
    entries = []
    blobs = []
    offset = 0
    for name, arr in tensors:
        entries.append(
            {
                "name": name,
                "shape": list(arr.shape),
                "offset": offset,
                "numel": int(arr.size),
            }
        )
        blobs.append(arr.tobytes())
        offset += int(arr.size)
    (outdir / "weights.bin").write_bytes(b"".join(blobs))

    images, labels = build_testset()
    (outdir / "testset.bin").write_bytes(images.tobytes() + labels.tobytes())

    manifest = {
        "spec": SPEC,
        "checkpoint_record": {
            "note": "untrained random-init fixture (export_fixture.py)"
        },
        "layers": conv_layer_shapes(),
        "models": [],
        "mvms": [],
        "weights": {
            "file": "weights.bin",
            "tensors": entries,
            "total_f32": offset,
        },
        "testset": {
            "file": "testset.bin",
            "dataset": "synth",
            "n": TESTSET_N,
            "image_shape": [
                SPEC["image_size"],
                SPEC["image_size"],
                SPEC["in_channels"],
            ],
        },
    }
    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def main() -> None:
    manifest = export(OUT)
    total = manifest["weights"]["total_f32"]
    print(f"wrote tiny_inhomo fixture to {OUT} ({total} f32 weights)")


if __name__ == "__main__":
    main()
