"""AOT compile path: lower the trained StoX model to HLO-text artifacts.

This is the only bridge between python (author/compile time) and the Rust
coordinator (request time).  Python never runs on the request path: the
Rust runtime loads ``artifacts/*.hlo.txt`` with
``HloModuleProto::from_text_file``, compiles once on the PJRT CPU client
and executes from then on.

Interchange is HLO **text**, not a serialized HloModuleProto: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Artifacts written to ``--outdir`` (default ``../artifacts``):

  * ``model_b{B}.hlo.txt``   — full model forward (weights baked in) for
                               each serving batch size; inputs
                               ``(x[B,H,W,C], seed u32)`` → logits[B,10]
  * ``mvm_{tag}.hlo.txt``    — standalone Pallas stochastic-MVM hot path
                               (the L1 kernel lowered inside jax.jit)
  * ``weights.bin``          — flat little-endian f32 dump of all params +
                               BN states for the Rust functional simulator
  * ``testset.bin``          — synth test images + labels for the Rust
                               end-to-end accuracy check
  * ``manifest.json``        — spec, tensor offsets, layer inventory, file
                               list (consumed by rust/src/runtime/registry)

Idempotent: ``make artifacts`` is a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import hashlib
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, model, train
from .kernels import stox as stox_kernels
from .kernels.ref import StoxConfig

DEFAULT_BATCHES = (1, 8)
E2E_CKPT = "e2e-cifar"


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning interchange).

    ``print_large_constants=True`` is essential: the default printer elides
    big literals as ``constant({...})``, which the text parser on the Rust
    side silently reloads as zeros — dropping every baked weight tensor.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _ensure_checkpoint(steps: int) -> Path:
    """Load (or quick-train) the model that the artifacts will serve."""
    ckpt = train.CHECKPOINTS / f"{E2E_CKPT}.pkl"
    if ckpt.exists():
        return ckpt
    print(f"[aot] no checkpoint at {ckpt}; quick-training {steps} steps")
    spec = train._spec(
        "cifar",
        name=E2E_CKPT,
        stox=StoxConfig(a_bits=4, w_bits=4, w_slice_bits=4, r_arr=256),
        first_layer="qf",
    )
    hp = dataclasses.replace(train.TrainHP(), steps=steps)
    record, params, states = train.train_model(spec, hp, "cifar")
    train.save_checkpoint(ckpt, spec, params, states, record)
    return ckpt


def export_model_hlo(spec, params, states, batch: int, outdir: Path) -> dict:
    """Lower the inference forward (Pallas kernels inside) to HLO text."""

    def serve_fn(x, seed):
        logits, _ = model.forward(
            params, states, x, spec, train=False, step_seed=seed,
            use_pallas=True,
        )
        return (logits,)

    x_spec = jax.ShapeDtypeStruct(
        (batch, spec.image_size, spec.image_size, spec.in_channels), jnp.float32
    )
    seed_spec = jax.ShapeDtypeStruct((), jnp.uint32)
    lowered = jax.jit(serve_fn).lower(x_spec, seed_spec)
    text = to_hlo_text(lowered)
    name = f"model_b{batch}.hlo.txt"
    (outdir / name).write_text(text)
    print(f"[aot] wrote {name} ({len(text)//1024} KiB)")
    return {
        "file": name,
        "kind": "model",
        "batch": batch,
        "inputs": [
            {"shape": list(x_spec.shape), "dtype": "f32"},
            {"shape": [], "dtype": "u32"},
        ],
        "outputs": [{"shape": [batch, spec.num_classes], "dtype": "f32"}],
    }


def export_mvm_hlo(cfg: StoxConfig, b: int, m: int, n: int, outdir: Path) -> dict:
    """Lower one standalone stochastic MVM (the L1 Pallas kernel)."""

    def mvm_fn(a, w, seed):
        return (stox_kernels.stox_mvm_pallas(a, w, cfg, seed),)

    a_spec = jax.ShapeDtypeStruct((b, m), jnp.float32)
    w_spec = jax.ShapeDtypeStruct((m, n), jnp.float32)
    seed_spec = jax.ShapeDtypeStruct((), jnp.uint32)
    lowered = jax.jit(mvm_fn).lower(a_spec, w_spec, seed_spec)
    text = to_hlo_text(lowered)
    name = f"mvm_{cfg.tag}_r{cfg.r_arr}_s{cfg.n_samples}_b{b}x{m}x{n}.hlo.txt"
    (outdir / name).write_text(text)
    print(f"[aot] wrote {name} ({len(text)//1024} KiB)")
    return {
        "file": name,
        "kind": "mvm",
        "cfg": dataclasses.asdict(cfg),
        "b": b, "m": m, "n": n,
    }


def export_weights(spec, params, states, outdir: Path) -> dict:
    """Flat f32 dump + per-tensor offsets for the Rust functional model."""
    tensors = []
    blobs = []
    offset = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(
        {"params": params, "states": states}
    )
    for kp, leaf in flat:
        arr = np.asarray(leaf, np.float32)
        tensors.append(
            {
                "name": jax.tree_util.keystr(kp),
                "shape": list(arr.shape),
                "offset": offset,
                "numel": int(arr.size),
            }
        )
        blobs.append(arr.tobytes())
        offset += arr.size
    (outdir / "weights.bin").write_bytes(b"".join(blobs))
    print(f"[aot] wrote weights.bin ({offset*4//1024} KiB, {len(tensors)} tensors)")
    return {"file": "weights.bin", "tensors": tensors, "total_f32": offset}


def export_testset(spec, outdir: Path, n: int = 512) -> dict:
    """Held-out synthetic test set for the Rust E2E accuracy check."""
    dataset = "digits" if spec.in_channels == 1 else "cifar"
    _, (xte, yte) = datasets.get_dataset(dataset, 8, n, spec.image_size, seed=0)
    payload = xte.astype(np.float32).tobytes() + yte.astype(np.int32).tobytes()
    (outdir / "testset.bin").write_bytes(payload)
    print(f"[aot] wrote testset.bin ({len(payload)//1024} KiB)")
    return {
        "file": "testset.bin",
        "dataset": dataset,
        "n": n,
        "image_shape": [spec.image_size, spec.image_size, spec.in_channels],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", type=Path, default=Path("../artifacts"))
    ap.add_argument("--train-steps", type=int, default=200,
                    help="quick-train budget when no checkpoint exists")
    ap.add_argument("--batches", type=int, nargs="*", default=list(DEFAULT_BATCHES))
    args = ap.parse_args()
    outdir = args.outdir
    outdir.mkdir(parents=True, exist_ok=True)

    ckpt = _ensure_checkpoint(args.train_steps)
    spec, params, states, record = train.load_checkpoint(ckpt)
    print(f"[aot] serving model {spec.name}: test acc {record.get('test_acc')}")

    manifest = {
        "spec": dataclasses.asdict(spec) | {"stox": dataclasses.asdict(spec.stox)},
        "checkpoint_record": {
            k: v for k, v in record.items() if not isinstance(v, list)
        },
        "layers": model.conv_layer_shapes(spec),
        "models": [],
        "mvms": [],
    }
    for b in args.batches:
        manifest["models"].append(export_model_hlo(spec, params, states, b, outdir))

    # Hot-path MVM artifacts: the baseline config + a multi-sample variant,
    # sized like a mid-network ResNet-20 layer (K=3·3·64=576 rows, 64 cols).
    base = spec.stox
    manifest["mvms"].append(export_mvm_hlo(base, 8, 576, 64, outdir))
    manifest["mvms"].append(
        export_mvm_hlo(dataclasses.replace(base, n_samples=4), 8, 576, 64, outdir)
    )

    manifest["weights"] = export_weights(spec, params, states, outdir)
    manifest["testset"] = export_testset(spec, outdir)

    text = json.dumps(manifest, indent=1)
    (outdir / "manifest.json").write_text(text)
    print(f"[aot] wrote manifest.json (sha256 {hashlib.sha256(text.encode()).hexdigest()[:12]})")


if __name__ == "__main__":
    main()
