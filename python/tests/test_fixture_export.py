"""The committed tiny checkpoint fixture (``compile/export_fixture.py``).

``rust/tests/data/tiny_inhomo`` pins manifest-driven converter selection
(mode ``"inhomo:base=1,extra=3"``) and the shared-weight-programming
regression tests on the Rust side; here we pin that the committed bytes
are exactly what a fresh deterministic export produces, and that the
manifest is internally consistent (offsets, sizes, layer inventory).
"""

import json
import pathlib

import numpy as np

from compile import export_fixture as fx

COMMITTED = (
    pathlib.Path(__file__).resolve().parents[2]
    / "rust"
    / "tests"
    / "data"
    / "tiny_inhomo"
)


def test_export_is_deterministic_and_matches_committed(tmp_path):
    fx.export(tmp_path)
    for name in ("manifest.json", "weights.bin", "testset.bin"):
        fresh = (tmp_path / name).read_bytes()
        committed = (COMMITTED / name).read_bytes()
        assert fresh == committed, f"{name} drifted from the committed fixture"


def test_manifest_mode_is_extended_registry_string():
    manifest = json.loads((COMMITTED / "manifest.json").read_text())
    mode = manifest["spec"]["stox"]["mode"]
    assert mode == "inhomo:base=1,extra=3"
    # the extended grammar, not a bare builtin name
    assert ":" in mode and "=" in mode
    assert manifest["spec"]["first_layer"] == "qf"


def test_weights_offsets_are_contiguous_and_sized():
    manifest = json.loads((COMMITTED / "manifest.json").read_text())
    weights = manifest["weights"]
    offset = 0
    for t in weights["tensors"]:
        assert t["offset"] == offset, t["name"]
        numel = int(np.prod(t["shape"])) if t["shape"] else 1
        assert numel == t["numel"], t["name"]
        offset += t["numel"]
    assert offset == weights["total_f32"]
    blob = (COMMITTED / "weights.bin").read_bytes()
    assert len(blob) == 4 * weights["total_f32"]


def test_testset_shapes_and_ranges():
    manifest = json.loads((COMMITTED / "manifest.json").read_text())
    ts = manifest["testset"]
    h, w, c = ts["image_shape"]
    blob = (COMMITTED / "testset.bin").read_bytes()
    n = ts["n"]
    img_f32 = n * h * w * c
    assert len(blob) == 4 * img_f32 + 4 * n
    images = np.frombuffer(blob[: 4 * img_f32], np.float32)
    labels = np.frombuffer(blob[4 * img_f32 :], np.int32)
    assert np.all(np.abs(images) <= 1.0)
    assert np.all((labels >= 0) & (labels < manifest["spec"]["num_classes"]))


def test_layer_inventory_matches_tensor_shapes():
    manifest = json.loads((COMMITTED / "manifest.json").read_text())
    tensors = {t["name"]: t["shape"] for t in manifest["weights"]["tensors"]}
    for layer in manifest["layers"]:
        if layer["name"] == "conv1":
            shape = tensors["['params']['conv1']"]
        elif layer["name"] == "fc":
            assert tensors["['params']['fc_w']"] == [layer["cin"], layer["cout"]]
            continue
        else:
            s, b, which = int(layer["name"][1]), int(layer["name"][3]), layer["name"][4:]
            shape = tensors[f"['params']['stages'][{s}][{b}]['{which[0] + 'onv' + which[1]}']"]
        assert shape == [layer["kh"], layer["kw"], layer["cin"], layer["cout"]], layer
