"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes / bit-widths / modes; every case asserts
allclose between ``stox.stox_mvm_pallas`` and ``ref.stox_mvm`` (and for the
stochastic mode the match must be *exact* because both sides draw the same
counter-based bits).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stox
from compile.kernels.ref import StoxConfig

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand_aw(b, m, n, seed=0):
    rs = np.random.RandomState(seed)
    a = jnp.asarray(rs.uniform(-1, 1, (b, m)), jnp.float32)
    w = jnp.asarray(rs.uniform(-1, 1, (m, n)), jnp.float32)
    return a, w


# ---------------------------------------------------------------------------
# Oracle self-consistency
# ---------------------------------------------------------------------------


class TestQuantizer:
    def test_roundtrip_exact_levels(self):
        for bits in (1, 2, 4, 8):
            lev = (1 << bits) - 1
            vals = jnp.asarray([2 * k / lev - 1 for k in range(lev + 1)])
            u = ref.quantize_unit(vals, bits)
            assert jnp.allclose(ref.dequantize_unit(u, bits), vals, atol=1e-6)

    def test_clipping(self):
        u = ref.quantize_unit(jnp.asarray([-5.0, 5.0]), 4)
        assert int(u[0]) == 0 and int(u[1]) == 15

    @given(
        bits=st.sampled_from([1, 2, 4, 8]),
        x=st.floats(-1, 1, width=32),
    )
    def test_quantization_error_bound(self, bits, x):
        lev = (1 << bits) - 1
        xq = ref.dequantize_unit(ref.quantize_unit(jnp.float32(x), bits), bits)
        assert abs(float(xq) - x) <= 1.0 / lev + 1e-6

    @given(
        bits=st.sampled_from([2, 4, 8]),
        digit_bits=st.sampled_from([1, 2]),
        u=st.integers(0, 255),
    )
    def test_digit_recomposition(self, bits, digit_bits, u):
        """sum_i 2^{i·d} x_i == 2u - (2^bits - 1) (signed digit identity)."""
        if bits % digit_bits:
            return
        u = u % (1 << bits)
        d = ref.signed_digits(jnp.asarray([u]), bits, digit_bits)
        s = ref.digit_scales(bits, digit_bits)
        recomposed = float((d[0] * s).sum())
        assert recomposed == 2 * u - ((1 << bits) - 1)


class TestOracle:
    def test_ideal_equals_plain_matmul(self):
        """Full-precision-ADC mode must equal a_q @ w_q / padded-rows."""
        a, w = rand_aw(4, 100, 17)
        cfg = StoxConfig(a_bits=8, w_bits=8, w_slice_bits=1, r_arr=64, mode="ideal")
        got = ref.stox_mvm(a, w, cfg)
        want = (a @ w) / (cfg.n_arrs(100) * cfg.r_arr)
        assert float(jnp.abs(got - want).max()) < 2e-2  # 8-bit quantization

    def test_output_bounded(self):
        a, w = rand_aw(3, 300, 9)
        for mode in ref.MODES:
            cfg = StoxConfig(r_arr=128, mode=mode, n_samples=3, w_slice_bits=1)
            out = ref.stox_mvm(a, w, cfg, seed=5)
            assert float(jnp.abs(out).max()) <= 1.0 + 1e-5, mode

    def test_stochastic_mean_converges_to_expected(self):
        a, w = rand_aw(2, 64, 8)
        cfg = StoxConfig(r_arr=64, alpha=2.0, n_samples=4, w_slice_bits=1)
        exp = ref.stox_mvm(a, w, dataclasses.replace(cfg, mode="expected"))
        acc = sum(ref.stox_mvm(a, w, cfg, seed=s) for s in range(64)) / 64
        # 64 seeds × 4 samples: sampling std of the recombined output ≈ 0.02
        assert float(jnp.abs(acc - exp).max()) < 0.07

    def test_sa_is_alpha_limit(self):
        """1b-SA == stochastic converter with a step-like tanh (alpha→inf).

        Uses an odd number of active rows so every PS is a sum of an odd
        number of odd digit products — never exactly 0, where sign() and
        the tanh limit legitimately disagree.
        """
        a, w = rand_aw(2, 63, 8)
        sa = ref.stox_mvm(a, w, StoxConfig(r_arr=63, mode="sa", w_slice_bits=1))
        hard = ref.stox_mvm(
            a, w,
            StoxConfig(r_arr=63, mode="expected", alpha=1e4, w_slice_bits=1),
        )
        assert float(jnp.abs(sa - hard).max()) < 1e-3

    def test_more_samples_lower_variance(self):
        a, w = rand_aw(2, 128, 8)
        errs = []
        for n in (1, 4, 16):
            cfg = StoxConfig(r_arr=128, n_samples=n, alpha=2.0, w_slice_bits=1)
            exp = ref.stox_mvm(a, w, dataclasses.replace(cfg, mode="expected"))
            out = ref.stox_mvm(a, w, cfg, seed=3)
            errs.append(float(jnp.square(out - exp).mean()))
        assert errs[0] > errs[1] > errs[2]

    def test_seed_determinism(self):
        a, w = rand_aw(2, 100, 8)
        cfg = StoxConfig(r_arr=64, w_slice_bits=1)
        o1 = ref.stox_mvm(a, w, cfg, seed=9)
        o2 = ref.stox_mvm(a, w, cfg, seed=9)
        o3 = ref.stox_mvm(a, w, cfg, seed=10)
        assert jnp.array_equal(o1, o2)
        assert not jnp.array_equal(o1, o3)

    def test_config_validation(self):
        with pytest.raises(ValueError):
            StoxConfig(a_bits=4, a_stream_bits=3)
        with pytest.raises(ValueError):
            StoxConfig(w_bits=4, w_slice_bits=3)
        with pytest.raises(ValueError):
            StoxConfig(mode="bogus")
        with pytest.raises(ValueError):
            StoxConfig(n_samples=0)


# ---------------------------------------------------------------------------
# Pallas kernel parity (the headline test)
# ---------------------------------------------------------------------------


class TestPallasParity:
    @pytest.mark.parametrize("mode", ref.MODES)
    def test_modes_match_ref(self, mode):
        a, w = rand_aw(4, 100, 150)
        cfg = StoxConfig(
            a_bits=4, w_bits=4, w_slice_bits=1, r_arr=64,
            n_samples=3, alpha=2.0, mode=mode,
        )
        r1 = ref.stox_mvm(a, w, cfg, seed=7)
        r2 = stox.stox_mvm_pallas(a, w, cfg, seed=7)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 6),
        m=st.integers(1, 200),
        n=st.integers(1, 160),
        a_bits=st.sampled_from([1, 2, 4]),
        w_bits_slice=st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 1), (4, 4)]),
        r_arr=st.sampled_from([32, 64, 256]),
        n_samples=st.integers(1, 4),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_sweep_stochastic(
        self, b, m, n, a_bits, w_bits_slice, r_arr, n_samples, seed
    ):
        w_bits, w_slice = w_bits_slice
        a, w = rand_aw(b, m, n, seed=seed % 1000)
        cfg = StoxConfig(
            a_bits=a_bits, w_bits=w_bits, w_slice_bits=w_slice,
            r_arr=r_arr, n_samples=n_samples, alpha=4.0, mode="stox",
        )
        r1 = ref.stox_mvm(a, w, cfg, seed=seed)
        r2 = stox.stox_mvm_pallas(a, w, cfg, seed=seed)
        # same counter-based bits on both sides -> exact match
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)

    def test_column_tiling_invariance(self):
        """Result must not depend on the kernel's column tile size."""
        a, w = rand_aw(2, 80, 200)
        cfg = StoxConfig(r_arr=64, w_slice_bits=1, n_samples=2)
        outs = [
            stox.stox_mvm_pallas(a, w, cfg, seed=3, col_tile=t)
            for t in (32, 64, 128, 200)
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5)

    def test_single_subarray_and_many(self):
        for m in (16, 64, 65, 300):
            a, w = rand_aw(2, m, 24)
            cfg = StoxConfig(r_arr=64, w_slice_bits=2, w_bits=4)
            r1 = ref.stox_mvm(a, w, cfg, seed=1)
            r2 = stox.stox_mvm_pallas(a, w, cfg, seed=1)
            np.testing.assert_allclose(
                np.asarray(r1), np.asarray(r2), atol=1e-5, err_msg=str(m)
            )


class TestConverterKernel:
    def test_matches_ref_counts(self):
        rs = np.random.RandomState(3)
        ps = jnp.asarray(rs.uniform(-1, 1, 333), jnp.float32)
        base = jnp.arange(333, dtype=jnp.uint32)
        for n_samples in (1, 2, 8):
            c1 = ref.mtj_sample_counts(ps, 3.0, n_samples, 9, base)
            c2 = stox.mtj_convert_pallas(ps, 3.0, n_samples, seed=9)
            assert jnp.array_equal(c1, c2), n_samples

    def test_counts_parity_bound(self):
        ps = jnp.zeros((64,), jnp.float32)
        c = stox.mtj_convert_pallas(ps, 4.0, 5, seed=0)
        # 5 samples of ±1: odd sum, |sum| <= 5
        cn = np.asarray(c)
        assert np.all(np.abs(cn) <= 5) and np.all(cn % 2 == 1)

    def test_probability_calibration(self):
        """Empirical switch rate must track tanh (Eq. 1)."""
        for x in (-0.5, -0.1, 0.0, 0.2, 0.6):
            ps = jnp.full((20000,), x, jnp.float32)
            c = np.asarray(stox.mtj_convert_pallas(ps, 2.0, 1, seed=42))
            emp = c.mean()  # E[±1] = tanh(αx)
            assert abs(emp - np.tanh(2.0 * x)) < 0.03, x
