"""The design-matrix sweep golden generator (``compile/gen_sweep_golden.py``).

Three layers of protection for ``rust/tests/data/sweep_golden.json``:

  * the generator's numpy MVM port (rust ``run_range`` operation order) is
    cross-checked against the *jnp* oracle through the committed
    ``mvm_golden.json`` vectors — bit-aligned stochastic draws, f32
    accumulation-order differences only;
  * the generator's counter RNG reproduces the shared known-answer vectors;
  * re-running the generator reproduces the committed golden (cost fields
    exactly — pure f64 — and accuracies to the libm-``tanh`` tolerance the
    Rust golden test also applies).  Skipped once the golden has been
    re-blessed from a Rust toolchain (``generator: "rust"``).
"""

import json
import pathlib

import numpy as np
import pytest

from compile import gen_sweep_golden as g

DATA = pathlib.Path(__file__).resolve().parents[2] / "rust" / "tests" / "data"


def test_counter_rng_known_answers():
    # same vectors as python/tests/test_rng.py and rust/src/stats/rng.rs
    counters = np.array([0, 1, 2, 3, 1000, 2**31, 2**32 - 1], np.uint32)
    want = [0xAE6F80F1, 0xA07C7A97, 0x0E77CEB6, 0x7E1BD18E, 0xD6663A0C,
            0x182BE288, 0x5F3DDEE1]
    got = g.mix32(counters ^ g.mix32(np.array([g._GOLDEN_MIX], np.uint32))[0])
    assert [int(x) for x in got] == want


def test_mvm_port_matches_oracle_golden_vectors():
    """Every committed mvm_golden case reproduces through the numpy port
    (rust-order accumulation) to the cross-backend f32 tolerance."""
    cases = json.loads((DATA / "mvm_golden.json").read_text())
    assert len(cases) >= 7
    for ci, c in enumerate(cases):
        cfg = g.Cfg(
            a_bits=c["a_bits"], w_bits=c["w_bits"], a_stream_bits=1,
            w_slice_bits=c["w_slice_bits"], r_arr=c["r_arr"],
            n_samples=c["n_samples"], alpha=c["alpha"],
        )
        mode = c["mode"]
        if mode == "stox":
            spec = f"stox:alpha={c['alpha']:g},samples={c['n_samples']}"
        elif mode == "sparse":
            spec = f"sparse:bits={c['bits']}"
        elif mode == "inhomo":
            spec = f"inhomo:alpha={c['alpha']:g},base={c['base']},extra={c['extra']}"
        elif mode == "expected":
            spec = f"expected:alpha={c['alpha']:g}"
        else:
            spec = mode
        a = np.array(c["a"], np.float32).reshape(c["b"], c["m"])
        w = np.array(c["w"], np.float32).reshape(c["m"], c["n"])
        out = g.Mvm(w, c["m"], c["n"], cfg).run(
            a, c["b"], g.Converter(spec, cfg), c["seed"]
        )
        want = np.array(c["out"], np.float32).reshape(out.shape)
        err = float(np.max(np.abs(out - want)))
        assert err < 1e-5, f"case {ci} ({mode}): max err {err}"


def test_precision_tags_parse():
    base = g.Cfg()
    c = g.cfg_from_tag("8w8a4bs", base)
    assert (c.w_bits, c.a_bits, c.w_slice_bits) == (8, 8, 4)
    assert c.tag == "8w8a4bs"
    assert c.r_arr == base.r_arr and c.alpha == base.alpha
    # slice width defaults from the base config when omitted
    assert g.cfg_from_tag("2w2a", base).w_slice_bits == 2


def test_pareto_flags_mark_the_staircase():
    pts = [(1.0, 100.0), (0.9, 10.0), (0.8, 50.0), (0.5, 1.0), (0.5, 1.0)]
    assert g.pareto_front_flags(pts) == [True, True, False, True, False]


def test_committed_sweep_golden_regenerates():
    path = DATA / "sweep_golden.json"
    envelope = json.loads(path.read_text())
    if envelope.get("generator") != "python-oracle":
        pytest.skip("golden re-blessed from a Rust toolchain")
    want = envelope["result"]
    got = g.run_fixed_sweep()
    assert got["workload"] == want["workload"]
    assert got["seed"] == want["seed"]
    assert len(got["points"]) == len(want["points"])
    tol = 3.0 / g.GOLDEN_INPUTS + 1e-12
    by_cell = {(p["tag"], p["spec"]): p for p in want["points"]}
    for p in got["points"]:
        w = by_cell[(p["tag"], p["spec"])]
        assert p["label"] == w["label"]
        # pure-f64 cost rollups are exact
        for key in ("energy_pj", "latency_ns", "area_um2", "edp_pj_ns",
                    "conversions", "xbars"):
            assert p[key] == w[key], (p["tag"], p["spec"], key)
        # f32 accuracies may drift by libm-tanh ulps across numpy builds
        assert abs(p["accuracy"] - w["accuracy"]) <= tol, (p["tag"], p["spec"])


def test_matrix_covers_paper_design_points():
    """The pinned golden carries HPFA-, SFA- and MTJ-class cells at both
    precision tags, ordered on EDP as in Fig. 9a."""
    envelope = json.loads((DATA / "sweep_golden.json").read_text())
    pts = {(p["tag"], p["spec"]): p for p in envelope["result"]["points"]}
    for tag in g.GOLDEN_TAGS:
        mtj = pts[(tag, "stox:alpha=4,samples=1")]
        sparse = pts[(tag, "sparse:bits=4")]
        fp = pts[(tag, "ideal")]
        assert mtj["edp_pj_ns"] < sparse["edp_pj_ns"] < fp["edp_pj_ns"]
        assert fp["accuracy"] == 1.0
    assert pts[("4w4a4bs", "ideal")]["edp_pj_ns"] < pts[("8w8a4bs", "ideal")]["edp_pj_ns"]
