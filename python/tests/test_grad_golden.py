"""Tests of the gradient-golden generator (`compile/gen_grad_golden.py`).

The golden file is the contract `rust/tests/grad_equiv.rs` pins the Rust
backward against, so this suite checks (a) the committed bytes match a
fresh generation, (b) the conventions are self-consistent: the ideal
case equals the analytic collapsed gradient, the tanh surrogate matches
finite differences of its transfer curve, and captured PS are exact
digit-domain values.
"""

import json
import pathlib

import numpy as np
import pytest

from compile import gen_grad_golden as gg
from compile.gen_sweep_golden import F32

GOLDEN = (
    pathlib.Path(__file__).resolve().parents[2]
    / "rust"
    / "tests"
    / "data"
    / "grad_golden.json"
)


def test_committed_golden_matches_fresh_generation():
    fresh = json.dumps(gg.build_golden(), sort_keys=True, separators=(",", ":"))
    assert GOLDEN.exists(), "run python -m compile.gen_grad_golden"
    assert GOLDEN.read_text() == fresh


def test_generation_is_deterministic():
    a = json.dumps(gg.build_golden(), sort_keys=True)
    b = json.dumps(gg.build_golden(), sort_keys=True)
    assert a == b


def test_ideal_case_matches_collapsed_analytic_gradient():
    # for the identity surrogate the digit-STE VJP must equal the exact
    # gradient of the collapsed linear chain a_q @ w_q / (K·r_arr)
    cfg = gg.CFG_A
    b, m, n = 2, 40, 6
    a, w, g = gg.derive_inputs(55, b * m, m * n, b * n)
    a, w, g = a.reshape(b, m), w.reshape(m, n), g.reshape(b, n)
    d_a, d_w = gg.stox_matmul_backward_np(a, w, cfg, "ideal", g)
    from compile.gen_sweep_golden import quantize_unit

    k_n = cfg.n_arrs(m)
    lw = (1 << cfg.w_bits) - 1
    wq = (2.0 * quantize_unit(w, cfg.w_bits).astype(F32) / F32(lw) - F32(1.0)).astype(F32)
    want_a = (g @ wq.T) / F32(k_n * cfg.r_arr)
    assert np.abs(d_a - want_a).max() < 1e-6
    la = (1 << cfg.a_bits) - 1
    aq = (2.0 * quantize_unit(a, cfg.a_bits).astype(F32) / F32(la) - F32(1.0)).astype(F32)
    want_w = (aq.T @ g) / F32(k_n * cfg.r_arr)
    assert np.abs(d_w - want_w).max() < 1e-6


@pytest.mark.parametrize("alpha", [1.0, 4.0, 8.0])
def test_tanh_surrogate_matches_finite_difference(alpha):
    ps = np.linspace(-0.9, 0.9, 37).astype(F32)
    d = gg.surrogate_grad(f"stox:alpha={alpha}", alpha, ps)
    eps = 1e-3
    fd = (np.tanh(alpha * (ps + eps)) - np.tanh(alpha * (ps - eps))) / (2 * eps)
    assert np.abs(d - fd).max() < 1e-2 * alpha


def test_clip_and_hardtanh_surrogates():
    ps = np.asarray([-1.5, -1.0, -0.2, 0.0, 0.2, 1.0, 1.5], F32)
    d = gg.surrogate_grad("quant:bits=4", 4.0, ps)
    assert d.tolist() == [0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0]
    d = gg.surrogate_grad("sa", 4.0, ps)
    # |4·ps| <= 1 only for ps in [-0.25, 0.25]
    assert d.tolist() == [0.0, 0.0, 4.0, 4.0, 4.0, 0.0, 0.0]


def test_captured_ps_are_exact_digit_values():
    cfg = gg.CFG_B
    b, m, n = 2, 24, 5
    a, w = gg.derive_inputs(77, b * m, m * n)[:2]
    ps, _, _ = gg.capture_ps(a.reshape(b, m), w.reshape(m, n), cfg)
    # every PS is an integer multiple of 1/r_arr, exactly representable
    scaled = ps * F32(cfg.r_arr)
    assert np.array_equal(scaled, np.round(scaled))
