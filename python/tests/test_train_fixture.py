"""Tests of the numpy mirror trainer + the committed trained fixture
(`compile/train_fixture.py` → `rust/tests/data/tiny_inhomo_trained`).

One full training run is shared across the suite (module fixture); the
committed bytes are pinned against it, and the accuracy/margin claims
the Rust side (`rust/tests/train.rs`) relies on are asserted here with
headroom for last-ulp cross-language differences.
"""

import hashlib
import pathlib

import numpy as np
import pytest

from compile import export_fixture as ef
from compile import train_fixture as tf

TRAINED = (
    pathlib.Path(__file__).resolve().parents[2]
    / "rust"
    / "tests"
    / "data"
    / "tiny_inhomo_trained"
)


@pytest.fixture(scope="module")
def trained_run(tmp_path_factory):
    params, losses, accs = tf.run(verbose=False)
    out = tmp_path_factory.mktemp("trained_fixture")
    tf.export_trained(params, losses, out)
    return params, losses, accs, out


def _digest(d: pathlib.Path) -> dict:
    return {
        f.name: hashlib.sha256(f.read_bytes()).hexdigest()
        for f in sorted(d.iterdir())
    }


def test_committed_trained_fixture_matches_fresh_run(trained_run):
    _, _, _, out = trained_run
    assert TRAINED.exists(), "run python -m compile.train_fixture"
    assert _digest(TRAINED) == _digest(out)


def test_loss_decreases(trained_run):
    _, losses, _, _ = trained_run
    head = float(np.mean(losses[:5]))
    tail = float(np.mean(losses[-5:]))
    assert tail < 0.1 * head, f"loss {head} -> {tail}"


def test_trained_strictly_beats_random_init(trained_run):
    _, _, accs, _ = trained_run
    for seed, (random_acc, trained_acc) in accs.items():
        assert trained_acc > random_acc, f"seed {seed}: {random_acc} vs {trained_acc}"
        assert trained_acc == 1.0, f"seed {seed}: trained fixture must memorize"


def test_trained_margins_have_ulp_headroom(trained_run):
    params, _, _, _ = trained_run
    images, labels = ef.build_testset()
    margins = tf.logit_margins(params, images.astype(np.float32), labels, seed=0)
    assert min(margins) > 1.0, margins


def test_testset_bytes_match_random_init_fixture():
    random_ts = TRAINED.parent / "tiny_inhomo" / "testset.bin"
    assert (TRAINED / "testset.bin").read_bytes() == random_ts.read_bytes()


def test_manifest_mode_is_registry_resolved():
    import json

    manifest = json.loads((TRAINED / "manifest.json").read_text())
    assert manifest["spec"]["stox"]["mode"] == "inhomo:base=1,extra=3"
    assert manifest["checkpoint_record"]["trained_with"] == tf.BODY_SPEC
    assert manifest["weights"]["total_f32"] * 4 == (TRAINED / "weights.bin").stat().st_size
