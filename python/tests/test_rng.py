"""Counter-based RNG: known-answer vectors (shared with Rust) + statistics."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import rng

# The exact same vectors are asserted in rust/src/imc/rng.rs — any change to
# the hash breaks python<->rust stochastic parity and must update both.
KAT = {
    0: [0xAE6F80F1, 0xA07C7A97, 0x0E77CEB6, 0x7E1BD18E, 0xD6663A0C, 0x182BE288, 0x5F3DDEE1],
    1: [0x8E374FE0, 0xA290702B, 0xE80E9316, 0x1D6D21D7, 0xB5BE8342, 0xF3BF5257, 0xCA4D4754],
    0xDEADBEEF: [0x754AFAC9, 0x551C946E, 0x07CD45F7, 0x5A2886E3, 0x36964039, 0xA8862EEA, 0x94FB713E],
}
COUNTERS = [0, 1, 2, 3, 1000, 2**31, 2**32 - 1]


@pytest.mark.parametrize("seed", list(KAT))
def test_known_answer(seed):
    c = jnp.asarray(COUNTERS, dtype=jnp.uint32)
    h = rng.hash_counter(seed, c)
    assert [int(x) for x in h] == KAT[seed]


def test_uniform_range_and_precision():
    c = jnp.arange(1 << 14, dtype=jnp.uint32)
    u = np.asarray(rng.uniform01(7, c))
    assert u.min() >= 0.0 and u.max() < 1.0
    # top-24-bit construction: every value is a multiple of 2^-24
    assert np.all(u * (1 << 24) == np.round(u * (1 << 24)))


def test_uniform_mean_variance():
    c = jnp.arange(1 << 16, dtype=jnp.uint32)
    u = np.asarray(rng.uniform01(3, c))
    assert abs(u.mean() - 0.5) < 5e-3
    assert abs(u.var() - 1.0 / 12.0) < 5e-3


def test_seed_decorrelation():
    c = jnp.arange(4096, dtype=jnp.uint32)
    u1 = np.asarray(rng.uniform01(1, c))
    u2 = np.asarray(rng.uniform01(2, c))
    corr = np.corrcoef(u1, u2)[0, 1]
    assert abs(corr) < 0.05


def test_counter_stride_decorrelation():
    """Strided counters (as used by multi-sampling) must stay uniform."""
    for stride in (2, 4, 8):
        c = jnp.arange(8192, dtype=jnp.uint32) * stride
        u = np.asarray(rng.uniform01(11, c))
        assert abs(u.mean() - 0.5) < 1.5e-2, stride


def test_mix32_avalanche():
    """Single-bit input flips should change ~half the output bits."""
    x = jnp.asarray([123456789], dtype=jnp.uint32)
    base = int(rng.mix32(x)[0])
    flips = []
    for bit in range(32):
        y = int(rng.mix32(x ^ jnp.uint32(1 << bit))[0])
        flips.append(bin(base ^ y).count("1"))
    assert 10 < np.mean(flips) < 22
