"""Oracle semantics of the registry-only PS converters (sparse / inhomo).

These are the python-side definitions the Rust ``SparseAdcConv`` /
``InhomogeneousMtjConv`` are pinned against through the golden vectors
(``compile/gen_golden.py`` → ``rust/tests/data/mvm_golden.json`` →
``rust/tests/converter_equiv.rs``).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref


def _rand(seed: int, n: int) -> np.ndarray:
    rs = np.random.RandomState(seed)
    return (rs.rand(n).astype(np.float32) * 2.0 - 1.0).astype(np.float32)


def _case(mode: str, **kw) -> ref.StoxConfig:
    return ref.StoxConfig(r_arr=64, mode=mode, **kw)


def test_sparse_matches_plain_quant_on_dense_ps():
    a = _rand(1, 2 * 96).reshape(2, 96)
    w = _rand(2, 96 * 5).reshape(96, 5)
    cfg = _case("sparse", sparse_bits=4)
    ps = ref.partial_sums(jnp.asarray(a), jnp.asarray(w), cfg)
    conv = ref.sparse_adc_convert(ps, 4)
    # random PS are essentially never all-zero per column slice, so the
    # sparse path must agree with the plain midtread quantizer
    assert np.allclose(np.asarray(conv), np.asarray(ref.quant_midtread(ps, 4)))


def test_sparse_skips_all_zero_column_slices():
    ps = jnp.zeros((1, 1, 6, 2, 2), jnp.float32)
    out = np.asarray(ref.sparse_adc_convert(ps, 4))
    assert (out == 0.0).all()
    # a real 4b midtread ADC would read 1/15, not 0 — the skip is the
    # approximation that buys the energy
    assert float(ref.quant_midtread(jnp.float32(0.0), 4)) != 0.0


def test_inhomo_table_monotone_and_clamped():
    cfg = _case("inhomo", w_slice_bits=1, base_samples=1, extra_samples=3)
    table = ref.inhomo_sample_table(cfg)  # 4 streams x 4 slices
    assert table[0][0] == 1 and table[3][3] == 4
    flat = [n for row in table for n in row]
    assert min(flat) >= 1 and max(flat) <= 4
    for i in range(3):
        assert table[i + 1][0] >= table[i][0]
        assert table[0][i + 1] >= table[0][i]


def test_inhomo_with_no_extra_matches_uniform_stox():
    a = _rand(3, 2 * 64).reshape(2, 64)
    w = _rand(4, 64 * 5).reshape(64, 5)
    for base in (1, 2, 4):
        uni = ref.stox_mvm(
            jnp.asarray(a),
            jnp.asarray(w),
            _case("stox", n_samples=base),
            seed=7,
        )
        inh = ref.stox_mvm(
            jnp.asarray(a),
            jnp.asarray(w),
            _case("inhomo", base_samples=base, extra_samples=0),
            seed=7,
        )
        # identical draws; only where the 1/n normalization is applied
        # differs, so agreement is to f32 rounding
        assert np.abs(np.asarray(uni) - np.asarray(inh)).max() < 1e-5


def test_inhomo_outputs_bounded_and_deterministic():
    a = _rand(5, 2 * 96).reshape(2, 96)
    w = _rand(6, 96 * 4).reshape(96, 4)
    cfg = _case("inhomo", w_slice_bits=1, base_samples=1, extra_samples=3)
    o1 = np.asarray(ref.stox_mvm(jnp.asarray(a), jnp.asarray(w), cfg, seed=3))
    o2 = np.asarray(ref.stox_mvm(jnp.asarray(a), jnp.asarray(w), cfg, seed=3))
    assert (o1 == o2).all()
    assert np.abs(o1).max() <= 1.0 + 1e-5


def test_inhomo_more_extra_reduces_variance():
    a = _rand(8, 1 * 128).reshape(1, 128)
    w = _rand(9, 128 * 6).reshape(128, 6)
    exp = np.asarray(
        ref.stox_mvm(jnp.asarray(a), jnp.asarray(w), _case("expected"), seed=0)
    )

    def mse(extra: int) -> float:
        cfg = _case(
            "inhomo", w_slice_bits=1, base_samples=1, extra_samples=extra
        )
        acc = 0.0
        for s in range(16):
            o = np.asarray(
                ref.stox_mvm(jnp.asarray(a), jnp.asarray(w), cfg, seed=s)
            )
            acc += float(((o - exp) ** 2).mean())
        return acc / 16

    assert mse(15) < mse(0)


def test_mode_validation():
    import pytest

    with pytest.raises(ValueError):
        ref.StoxConfig(mode="bogus")
    with pytest.raises(ValueError):
        ref.StoxConfig(mode="sparse", sparse_bits=0)
    with pytest.raises(ValueError):
        ref.StoxConfig(mode="inhomo", base_samples=0)
    # frozen dataclass still supports replace-based mode switches
    cfg = dataclasses.replace(ref.StoxConfig(), mode="sparse")
    assert cfg.mode == "sparse"
