"""Model-level tests: shapes, variants, determinism, train-step smoke."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, model, train
from compile.kernels.ref import StoxConfig

TINY = model.ModelSpec(
    name="tiny",
    in_channels=3,
    image_size=8,
    base_width=8,
    width_mult=0.5,
    blocks_per_stage=1,
    stox=StoxConfig(a_bits=2, w_bits=2, w_slice_bits=2, r_arr=32),
    first_layer="qf",
    first_layer_samples=2,
)


def fwd(spec, x, train_=False, seed=0):
    params, states = model.init_params(spec, jax.random.PRNGKey(0))
    return model.forward(params, states, x, spec, train=train_, step_seed=seed)


class TestForward:
    def test_output_shape(self):
        x = jnp.zeros((4, 8, 8, 3))
        logits, _ = fwd(TINY, x)
        assert logits.shape == (4, 10)

    def test_hpf_variant(self):
        spec = dataclasses.replace(TINY, first_layer="hpf")
        logits, _ = fwd(spec, jnp.zeros((2, 8, 8, 3)))
        assert logits.shape == (2, 10)

    def test_seed_determinism(self):
        x = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (2, 8, 8, 3)), jnp.float32)
        l1, _ = fwd(TINY, x, seed=3)
        l2, _ = fwd(TINY, x, seed=3)
        l3, _ = fwd(TINY, x, seed=4)
        assert jnp.array_equal(l1, l2)
        assert not jnp.array_equal(l1, l3)

    def test_pallas_forward_matches_ref_forward(self):
        x = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (2, 8, 8, 3)), jnp.float32)
        params, states = model.init_params(TINY, jax.random.PRNGKey(0))
        l1, _ = model.forward(params, states, x, TINY, step_seed=1, use_pallas=False)
        l2, _ = model.forward(params, states, x, TINY, step_seed=1, use_pallas=True)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)

    def test_bn_states_update_in_train(self):
        x = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (8, 8, 8, 3)), jnp.float32)
        params, states = model.init_params(TINY, jax.random.PRNGKey(0))
        _, ns = model.forward(params, states, x, TINY, train=True)
        assert not jnp.array_equal(ns["bn1"]["mean"], states["bn1"]["mean"])


class TestSpec:
    def test_widths_scale(self):
        assert TINY.widths() == (4, 8, 16)
        assert dataclasses.replace(TINY, width_mult=1.0).widths() == (8, 16, 32)

    def test_layer_cfg_first_layer(self):
        cfg0 = TINY.layer_cfg(0)
        assert cfg0.n_samples == TINY.first_layer_samples
        assert TINY.layer_cfg(1).n_samples == TINY.stox.n_samples

    def test_layer_cfg_mix(self):
        spec = dataclasses.replace(TINY, layer_samples=((2, 4), (3, 2)))
        assert spec.layer_cfg(2).n_samples == 4
        assert spec.layer_cfg(3).n_samples == 2
        assert spec.layer_cfg(4).n_samples == spec.stox.n_samples

    def test_first_layer_mode_override(self):
        spec = dataclasses.replace(TINY, first_layer_mode="sa")
        assert spec.layer_cfg(0).mode == "sa"
        assert spec.layer_cfg(1).mode == "stox"

    def test_n_stox_layers(self):
        assert TINY.n_stox_layers() == 2 * 3 * 1 + 1
        hpf = dataclasses.replace(TINY, first_layer="hpf")
        assert hpf.n_stox_layers() == 6

    def test_conv_layer_shapes_inventory(self):
        layers = model.conv_layer_shapes(TINY)
        # conv1 + 2 per block * 3 stages * 1 block + fc
        assert len(layers) == 1 + 6 + 1
        assert layers[0]["name"] == "conv1" and layers[0]["stochastic"]
        assert layers[-1]["name"] == "fc" and not layers[-1]["stochastic"]
        # stride-2 stages halve resolution
        assert layers[3]["h_out"] == TINY.image_size // 2
        assert layers[5]["h_out"] == TINY.image_size // 4


class TestTraining:
    def test_loss_decreases_smoke(self):
        hp = dataclasses.replace(
            train.TrainHP(), steps=30, batch=16, n_train=256, n_test=64
        )
        rec, params, states = train.train_model(TINY, hp, "cifar", verbose=False)
        assert rec["loss_curve"][0] > rec["final_loss"]
        assert np.isfinite(rec["final_loss"])

    def test_checkpoint_roundtrip(self, tmp_path):
        hp = dataclasses.replace(
            train.TrainHP(), steps=2, batch=8, n_train=64, n_test=32
        )
        rec, params, states = train.train_model(TINY, hp, "cifar", verbose=False)
        path = tmp_path / "ckpt.pkl"
        train.save_checkpoint(path, TINY, params, states, rec)
        spec2, p2, s2, rec2 = train.load_checkpoint(path)
        assert spec2 == TINY
        for a, b in zip(
            jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)
        ):
            assert jnp.array_equal(a, b)

    def test_mix_from_sensitivity(self):
        sens = [
            {"layer": i, "acc_drop": d}
            for i, d in enumerate([0.5, 0.3, 0.1, 0.05, 0.02, 0.01, 0.0, 0.0])
        ]
        mix = train.mix_from_sensitivity(sens, 8)
        mix_d = dict(mix)
        # layer 0 (conv-1) excluded; most sensitive non-first layers get 4
        assert 0 not in mix_d
        assert mix_d[1] == 4
        assert all(v in (2, 4) for v in mix_d.values())


class TestDatasets:
    @pytest.mark.parametrize("name", ["digits", "cifar"])
    def test_shapes_and_range(self, name):
        (xtr, ytr), (xte, yte) = datasets.get_dataset(name, 64, 32, 16, seed=1)
        c = 1 if name == "digits" else 3
        assert xtr.shape == (64, 16, 16, c) and xte.shape == (32, 16, 16, c)
        assert xtr.min() >= -1 and xtr.max() <= 1
        assert set(np.unique(ytr)) <= set(range(10))

    def test_determinism(self):
        x1, y1 = datasets.synth_cifar(16, 16, seed=5)
        x2, y2 = datasets.synth_cifar(16, 16, seed=5)
        assert np.array_equal(x1, x2) and np.array_equal(y1, y2)

    def test_train_test_disjoint_seeds(self):
        (xtr, _), (xte, _) = datasets.get_dataset("digits", 32, 32, 16, seed=0)
        assert not np.array_equal(xtr, xte)
