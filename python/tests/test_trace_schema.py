"""Chrome-trace JSON schema validation for ``stox-cli serve --trace``.

The span exporter (``rust/src/obs/span.rs``) writes the Trace Event
Format that ``chrome://tracing`` / Perfetto consume: a top-level object
with a ``traceEvents`` array of ``X`` (complete), ``B``/``E``
(duration), and ``i`` (instant) events.  ``validate_trace`` pins the
subset the exporter promises; pytest runs it over an embedded sample
and over any trace the CI ``obs-smoke`` job produced, and the module
doubles as a standalone checker::

    python tests/test_trace_schema.py trace.json
"""

import json
import numbers
import pathlib
import re
import sys

_PHASES = {"X", "B", "E", "i"}

# event names the instrumentation emits; a trace may carry any subset
# (timing-dependent paths like steal/hedge fire under load), but must
# not invent names outside the documented schema.  Per-layer spans are
# named dynamically ("conv.l00", ...) and the kernel level adds
# "stripe" events — see _name_ok.
KNOWN_NAMES = {
    "admission.reject",
    "queue_wait",
    "dispatch",
    "execute",
    "steal",
    "hedge",
    "requeue",
    "evict",
    "deadline.exceeded",
    "stripe",
}

_LAYER_RE = re.compile(r"^conv\.l\d{2,}$")


def _name_ok(name):
    return name in KNOWN_NAMES or _LAYER_RE.match(name) is not None


def _is_num(v):
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


def validate_trace(doc):
    """Validate a parsed trace document; returns the event list.

    Raises ``AssertionError`` with a readable message on any violation.
    """
    assert isinstance(doc, dict), "trace root must be a JSON object"
    assert "traceEvents" in doc, "trace root missing 'traceEvents'"
    events = doc["traceEvents"]
    assert isinstance(events, list), "'traceEvents' must be an array"
    if "displayTimeUnit" in doc:
        assert doc["displayTimeUnit"] in ("ms", "ns"), (
            f"bad displayTimeUnit {doc['displayTimeUnit']!r}"
        )
    for idx, e in enumerate(events):
        where = f"traceEvents[{idx}]"
        assert isinstance(e, dict), f"{where} must be an object"
        for key in ("name", "cat", "ph", "ts", "pid", "tid"):
            assert key in e, f"{where} missing '{key}'"
        assert isinstance(e["name"], str) and e["name"], f"{where} bad name"
        assert isinstance(e["cat"], str) and e["cat"], f"{where} bad cat"
        assert e["ph"] in _PHASES, f"{where} unknown phase {e['ph']!r}"
        assert _is_num(e["ts"]) and e["ts"] >= 0, f"{where} bad ts"
        assert _is_num(e["pid"]), f"{where} bad pid"
        assert _is_num(e["tid"]), f"{where} bad tid"
        if e["ph"] == "X":
            assert _is_num(e.get("dur")) and e["dur"] >= 0, f"{where} bad dur"
        if e["ph"] == "i":
            assert e.get("s") in ("t", "p", "g"), f"{where} bad instant scope"
        if "args" in e:
            assert isinstance(e["args"], dict), f"{where} args must be an object"
    return events


def validate_file(path):
    events = validate_trace(json.loads(pathlib.Path(path).read_text()))
    unknown = {e["name"] for e in events if not _name_ok(e["name"])}
    assert not unknown, f"undocumented event names: {sorted(unknown)}"
    return events


# one event of each phase the exporter emits, in its field layout
_SAMPLE = {
    "traceEvents": [
        {"name": "dispatch", "cat": "serve", "ph": "X", "ts": 12.5,
         "pid": 0, "tid": 1, "dur": 840.0, "args": {"batch": 4}},
        {"name": "queue_wait", "cat": "serve", "ph": "X", "ts": 2.0,
         "pid": 0, "tid": 1, "dur": 10.5},
        {"name": "steal", "cat": "serve", "ph": "i", "ts": 900.0,
         "pid": 0, "tid": 2, "s": "t", "args": {"from": 0}},
    ],
    "displayTimeUnit": "ms",
}


def test_embedded_sample_validates():
    events = validate_trace(_SAMPLE)
    assert len(events) == 3
    assert {e["ph"] for e in events} == {"X", "i"}


def test_violations_are_loud():
    import copy

    for mutate in (
        lambda d: d.pop("traceEvents"),
        lambda d: d["traceEvents"][0].pop("ts"),
        lambda d: d["traceEvents"][0].update(ph="Q"),
        lambda d: d["traceEvents"][0].update(dur=-1),
        lambda d: d["traceEvents"][2].pop("s"),
    ):
        bad = copy.deepcopy(_SAMPLE)
        mutate(bad)
        try:
            validate_trace(bad)
        except AssertionError:
            continue
        raise AssertionError(f"mutation {mutate} should have failed validation")


def test_ci_trace_if_present():
    """When the obs-smoke job (or a developer) left a trace next to the
    repo, validate it end-to-end; skipped otherwise."""
    import pytest

    candidates = [
        pathlib.Path("/tmp/trace.json"),
        pathlib.Path(__file__).resolve().parents[2] / "trace.json",
    ]
    path = next((p for p in candidates if p.exists()), None)
    if path is None:
        pytest.skip("no serve --trace output present")
    validate_file(path)


if __name__ == "__main__":
    if len(sys.argv) != 2:
        sys.exit("usage: python tests/test_trace_schema.py <trace.json>")
    evs = validate_file(sys.argv[1])
    print(f"{sys.argv[1]}: {len(evs)} events, schema OK")
