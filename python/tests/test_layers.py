"""L2 layer tests: STE gradients, conv lowering, batchnorm."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import stox_layers as sl
from compile.kernels import ref
from compile.kernels.ref import StoxConfig


def rand(shape, seed=0, lo=-1, hi=1):
    rs = np.random.RandomState(seed)
    return jnp.asarray(rs.uniform(lo, hi, shape), jnp.float32)


class TestSTEQuantize:
    def test_forward_matches_ref(self):
        x = rand((64,), 1)
        for bits in (1, 2, 4):
            want = ref.dequantize_unit(ref.quantize_unit(x, bits), bits)
            got = sl.ste_quantize_unit(x, bits)
            assert jnp.allclose(got, want)

    def test_gradient_identity_inside(self):
        g = jax.grad(lambda x: sl.ste_quantize_unit(x, 4).sum())(
            jnp.asarray([-0.9, -0.3, 0.0, 0.5, 0.99])
        )
        assert jnp.allclose(g, 1.0)

    def test_gradient_zero_outside(self):
        g = jax.grad(lambda x: sl.ste_quantize_unit(x, 4).sum())(
            jnp.asarray([-1.5, 2.0])
        )
        assert jnp.allclose(g, 0.0)


class TestStoxMatmul:
    def test_forward_is_hardware_exact(self):
        a, w = rand((4, 96), 0), rand((96, 12), 1)
        cfg = StoxConfig(r_arr=64, w_slice_bits=1, n_samples=2)
        got = sl.stox_matmul(a, w, jnp.uint32(5), cfg)
        want = ref.stox_mvm(a, w, cfg, seed=jnp.uint32(5))
        assert jnp.array_equal(got, want)

    def test_pallas_path_matches(self):
        a, w = rand((4, 96), 0), rand((96, 12), 1)
        cfg = StoxConfig(r_arr=64, w_slice_bits=1, n_samples=2)
        got = sl.stox_matmul(a, w, jnp.uint32(5), cfg, True)
        want = ref.stox_mvm(a, w, cfg, seed=jnp.uint32(5))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_gradients_nonzero_and_finite(self):
        a, w = rand((4, 96), 0), rand((96, 12), 1)
        cfg = StoxConfig(r_arr=64, w_slice_bits=1)

        def loss(a_, w_):
            return jnp.square(sl.stox_matmul(a_, w_, jnp.uint32(0), cfg)).sum()

        ga, gw = jax.grad(loss, argnums=(0, 1))(a, w)
        assert jnp.all(jnp.isfinite(ga)) and jnp.all(jnp.isfinite(gw))
        assert float(jnp.abs(ga).max()) > 0 and float(jnp.abs(gw).max()) > 0

    def test_surrogate_gradient_matches_linear_in_small_alpha(self):
        """For alpha→0 the surrogate is linear, grad ≈ ideal matmul grad."""
        a, w = rand((2, 64), 3), rand((64, 6), 4)
        cfg = StoxConfig(r_arr=64, alpha=1e-3, mode="expected", a_bits=8, w_bits=8, w_slice_bits=1)
        g = jnp.ones((2, 6))
        _, vjp = jax.vjp(lambda a_, w_: sl._surrogate_mvm(a_, w_, cfg), a, w)
        ga, gw = vjp(g)
        # d/da of alpha * (a @ w)/r_arr = alpha * g @ w.T / r_arr
        want = 1e-3 * (g @ w.T) / 64.0
        # f32 einsum noise on ~1e-5-magnitude gradients needs a real atol
        np.testing.assert_allclose(np.asarray(ga), np.asarray(want), rtol=0.05, atol=5e-7)

    def test_saturation_clamps_gradient(self):
        """Gradient through saturated PS regions must vanish (paper's STE clamp)."""
        a = jnp.ones((1, 64))
        w = jnp.ones((64, 1))
        cfg = StoxConfig(r_arr=64, alpha=50.0)  # deep saturation

        def loss(w_):
            return sl.stox_matmul(a, w_, jnp.uint32(0), cfg).sum()

        gw = jax.grad(loss)(w)
        assert float(jnp.abs(gw).max()) < 1e-6


class TestConv:
    def test_im2col_matches_conv(self):
        """stox conv in ideal high-precision mode ≈ scaled fp conv."""
        x = rand((2, 8, 8, 3), 0)
        w = rand((3, 3, 3, 5), 1, -0.5, 0.5)
        cfg = StoxConfig(a_bits=8, w_bits=8, w_slice_bits=1, r_arr=27, mode="ideal")
        got = sl.stox_conv2d(x, w, jnp.uint32(0), cfg)
        wn = sl.normalize_weights(w)
        want = sl.fp_conv2d(x, wn) / 27.0
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-2)

    def test_strided(self):
        x = rand((2, 8, 8, 4), 0)
        w = rand((3, 3, 4, 6), 1)
        cfg = StoxConfig(r_arr=36, mode="ideal")
        out = sl.stox_conv2d(x, w, jnp.uint32(0), cfg, stride=2)
        assert out.shape == (2, 4, 4, 6)

    def test_1x1(self):
        x = rand((2, 5, 5, 4), 0)
        w = rand((1, 1, 4, 8), 1)
        cfg = StoxConfig(r_arr=4, mode="ideal")
        out = sl.stox_conv2d(x, w, jnp.uint32(0), cfg)
        assert out.shape == (2, 5, 5, 8)


class TestBatchNorm:
    def test_normalizes_in_train(self):
        p, s = sl.bn_init(4)
        x = rand((64, 3, 3, 4), 0, -5, 5) + 2.0
        y, s2 = sl.batch_norm(x, p, s, train=True)
        assert abs(float(y.mean())) < 1e-4
        assert abs(float(y.var()) - 1.0) < 1e-2
        # running stats moved toward batch stats
        assert float(jnp.abs(s2["mean"]).max()) > 0

    def test_eval_uses_running_stats(self):
        p, s = sl.bn_init(4)
        x = rand((8, 2, 2, 4), 1)
        y, s2 = sl.batch_norm(x, p, s, train=False)
        assert jnp.array_equal(s2["mean"], s["mean"])
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(x) / np.sqrt(1 + 1e-5), atol=1e-5
        )


class TestActClip:
    def test_range(self):
        x = rand((100,), 0, -3, 3)
        y = sl.act_clip(x)
        assert float(y.min()) >= -1 and float(y.max()) <= 1

    def test_grad_mask(self):
        g = jax.grad(lambda x: sl.act_clip(x).sum())(jnp.asarray([-2.0, 0.5, 2.0]))
        assert list(np.asarray(g)) == [0.0, 1.0, 0.0]
